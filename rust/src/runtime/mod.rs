//! PJRT runtime: load AOT artifacts and run LKGP inference from rust.
//!
//! The request path is: coordinator -> [`Engine`] -> compiled executable
//! (HLO text loaded once per bucket, compiled once, cached) or the
//! pure-rust mirror. Python is never involved at runtime — `make
//! artifacts` is the only place jax runs.
//!
//! Shape buckets: HLO modules have static shapes, so a live problem
//! (n, m, d) is padded up to the smallest exported bucket — extra config
//! rows are fully masked (the masked operator is block-diagonal across the
//! mask, so padding is mathematically inert; see gp::operator tests) and
//! extra grid columns carry mask 0 as well. Outputs are sliced back.
//!
//! [`Engine`] abstracts over the XLA path and the pure-rust engine so the
//! coordinator and benches can switch with a flag. The XLA path needs the
//! `xla` crate (not in the offline set), so `XlaEngine` is gated behind
//! the `xla` cargo feature; without it [`open_engine`] always returns the
//! rust engine.

pub mod chaos;
pub mod manifest;

#[cfg(feature = "xla")]
use std::collections::HashMap;
use std::path::Path;
use std::sync::Arc;

#[cfg(feature = "xla")]
use crate::error::LkgpError;
use crate::error::Result;
use crate::gp::lkgp::{Dataset, SolverCfg};
use crate::gp::operator::PrecondFactors;
use crate::gp::pathwise::PathLineage;
use crate::gp::session::{Answer, FitMethod, FitSession, Posterior, Query};
use crate::gp::trainer;
#[cfg(feature = "xla")]
use crate::gp::Theta;
use crate::linalg::Matrix;
use crate::rng::Pcg64;

pub use manifest::{ArtifactSpec, Manifest};

/// Result of a warm-startable final-value prediction.
pub struct PredictOutcome {
    /// (mean, variance) per query, standardized units.
    pub preds: Vec<(f64, f64)>,
    /// Converged training solve (flattened `(n, m)` alpha) for reuse as a
    /// warm start by the serving layer, when the engine exposes it.
    pub alpha: Option<Vec<f64>>,
    /// Converged cross-covariance solves (flattened `(q, n*m)`), reusable
    /// when the same queries repeat against the same training rows.
    pub cross: Option<Vec<f64>>,
    /// Total CG iterations across the batched solve (0 for engines that
    /// do not report iteration counts).
    pub cg_iters: usize,
    /// Total per-RHS operator rows applied (see `CgStats::mvm_rows`; 0
    /// for engines that do not report it).
    pub cg_mvm_rows: usize,
    /// Factored preconditioner state used/built by the solve, for the
    /// serving layer to cache in the `WarmStart` lineage (None when
    /// preconditioning is off or the engine does not expose it).
    pub precond: Option<Arc<PrecondFactors>>,
    /// Escalation-ladder rungs climbed by the solve (0 on the healthy
    /// path; docs/robustness.md).
    pub escalations: usize,
    /// Solves answered by the dense-Cholesky fallback rung.
    pub dense_fallbacks: usize,
}

/// Result of a typed-query batch ([`Engine::answer_batch`]): the answers
/// in submission order plus the converged solver state the serving layer
/// caches as `WarmStart` lineage.
pub struct QueryOutcome {
    /// One [`Answer`] per submitted [`Query`], in order.
    pub answers: Vec<Answer>,
    /// Converged training solve (flattened `(n, m)` alpha), when exposed.
    pub alpha: Option<Vec<f64>>,
    /// The stacked final-step query matrix the cross solves correspond to
    /// (the `gp::session::stacked_final_xq` layout of the batch).
    pub xq: Option<Matrix>,
    /// Converged cross-covariance solves matching `xq`.
    pub cross: Option<Vec<f64>>,
    /// Total per-RHS CG iterations across the batch's solves.
    pub cg_iters: usize,
    /// Total per-RHS operator rows applied (`CgStats::mvm_rows`).
    pub cg_mvm_rows: usize,
    /// Underlying batched solves run (session engines amortize a whole
    /// query batch into one; legacy mapping pays one per query).
    pub solves: usize,
    /// Factored preconditioner state after the batch.
    pub precond: Option<Arc<PrecondFactors>>,
    /// Escalation-ladder rungs climbed across the batch's solves.
    pub escalations: usize,
    /// Solves answered by the dense-Cholesky fallback rung.
    pub dense_fallbacks: usize,
    /// `CurveSamples` calls served pathwise with zero new CG solves
    /// (docs/sampling.md).
    pub pathwise_hits: usize,
    /// Factored-preconditioner applies spent drawing pathwise samples
    /// (one per sample; the marginal cost BENCH_samples.json gates).
    pub sample_mvms: usize,
    /// Cached pathwise factorization (prior-path factors + query-cross
    /// blocks) for the serving layer to carry in the `WarmStart` lineage.
    pub path: Option<PathLineage>,
}

/// A GP backend the coordinator can drive.
pub trait Engine: Send {
    /// Optimize hyper-parameters from `theta0`; returns packed theta.
    fn fit(&mut self, theta0: &[f64], data: &Dataset, seed: u64) -> Result<Vec<f64>>;

    /// (mean, variance) of the final-epoch value for each query config
    /// (standardized units).
    fn predict_final(&mut self, theta: &[f64], data: &Dataset, xq: &Matrix)
        -> Result<Vec<(f64, f64)>>;

    /// Warm-startable final-value prediction: `warm` is an optional
    /// initial guess for the training solve (flattened `(n, m)` alpha).
    /// Engines without warm-start support fall back to [`Engine::predict_final`]
    /// and report no alpha.
    fn predict_final_warm(
        &mut self,
        theta: &[f64],
        data: &Dataset,
        xq: &Matrix,
        warm: Option<&[f64]>,
    ) -> Result<PredictOutcome> {
        let _ = warm;
        Ok(PredictOutcome {
            preds: self.predict_final(theta, data, xq)?,
            alpha: None,
            cross: None,
            cg_iters: 0,
            cg_mvm_rows: 0,
            precond: None,
            escalations: 0,
            dense_fallbacks: 0,
        })
    }

    /// [`Engine::predict_final_warm`] plus cached preconditioner state:
    /// `precond` is the previous generation's factored preconditioner
    /// (from the `WarmStart` lineage); the outcome carries the factors the
    /// solve actually used for re-caching. Engines without a
    /// preconditioned path ignore it.
    fn predict_final_cached(
        &mut self,
        theta: &[f64],
        data: &Dataset,
        xq: &Matrix,
        warm: Option<&[f64]>,
        precond: Option<Arc<PrecondFactors>>,
    ) -> Result<PredictOutcome> {
        let _ = precond;
        self.predict_final_warm(theta, data, xq, warm)
    }

    /// Answer a batch of typed queries against one model state. `warm` is
    /// an optional initial guess in the batch's stacked final-step layout
    /// (see `gp::session::stacked_final_xq`); `precond` is cached factored
    /// preconditioner lineage; `path` is cached pathwise-sampling lineage
    /// (docs/sampling.md). The default maps each query onto the legacy
    /// per-query entry points — correct but with no solve sharing — so
    /// artifact engines work unchanged; warm-capable engines override it
    /// to amortize the whole batch into one underlying solve.
    fn answer_batch(
        &mut self,
        theta: &[f64],
        data: &Arc<Dataset>,
        queries: &[Query],
        warm: Option<&[f64]>,
        precond: Option<Arc<PrecondFactors>>,
        path: Option<PathLineage>,
    ) -> Result<QueryOutcome> {
        let _ = (warm, precond, path);
        // same shape/level validation the session applies, so engines are
        // interchangeable: a malformed query errors instead of producing
        // engine-dependent output (e.g. NaN quantiles at p = 0)
        for q in queries {
            crate::gp::session::validate_query(data, q)?;
        }
        let mut answers = Vec::with_capacity(queries.len());
        let mut solves = 0usize;
        for q in queries {
            let ans = match q {
                Query::MeanAtFinal { xq } => {
                    solves += 1;
                    Answer::Final(self.predict_final(theta, data, xq)?)
                }
                Query::Variance { xq } => {
                    solves += 1;
                    Answer::Variance(
                        self.predict_final(theta, data, xq)?
                            .into_iter()
                            .map(|p| p.1)
                            .collect(),
                    )
                }
                Query::Quantiles { xq, ps } => {
                    solves += 1;
                    let preds = self.predict_final(theta, data, xq)?;
                    Answer::Quantiles(crate::gp::session::quantiles_from_preds(&preds, ps))
                }
                Query::MeanAtSteps { xq, steps } => {
                    solves += 1;
                    let full = self.predict_mean(theta, data, xq)?;
                    Answer::Steps(crate::gp::session::select_steps(&full, steps))
                }
                Query::CurveSamples { xq, n, seed } => {
                    solves += 1;
                    Answer::Curves(self.sample_curves(theta, data, xq, *n, *seed)?)
                }
                Query::Mll { .. } => {
                    return Err(crate::error::LkgpError::Coordinator(format!(
                        "engine '{}' does not serve Mll queries",
                        self.name()
                    )))
                }
            };
            answers.push(ans);
        }
        Ok(QueryOutcome {
            answers,
            alpha: None,
            xq: None,
            cross: None,
            cg_iters: 0,
            cg_mvm_rows: 0,
            solves,
            precond: None,
            escalations: 0,
            dense_fallbacks: 0,
            pathwise_hits: 0,
            sample_mvms: 0,
            path: None,
        })
    }

    /// Posterior samples of full curves over [X; Xq] x grid.
    fn sample_curves(
        &mut self,
        theta: &[f64],
        data: &Dataset,
        xq: &Matrix,
        s: usize,
        seed: u64,
    ) -> Result<Vec<Matrix>>;

    /// Posterior mean curves for query configs.
    fn predict_mean(&mut self, theta: &[f64], data: &Dataset, xq: &Matrix) -> Result<Matrix>;

    /// Solver configuration for read-only replica sessions. Engines whose
    /// query path runs through `gp::session` return their `SolverCfg` so a
    /// `coordinator::ServicePool` can serve read-only `Query` bursts from
    /// forked `Posterior`s on spare workers while the writer shard is
    /// busy (same solver settings ⇒ same answers as the writer). Engines
    /// with a different compute path (e.g. the XLA artifact engine) keep
    /// the default `None`, which disables replicas for their shards.
    fn session_cfg(&self) -> Option<SolverCfg> {
        None
    }

    /// Human-readable backend name (logs/metrics).
    fn name(&self) -> &'static str;
}

/// Artifacts directory (repo-relative, overridable by `LKGP_ARTIFACTS`).
/// Available without the `xla` feature so manifests can be inspected
/// everywhere.
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(dir) = std::env::var("LKGP_ARTIFACTS") {
        return dir.into();
    }
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

// ---------------------------------------------------------------------------
// Pure-rust engine

/// Hyper-parameter optimizer choice for [`RustEngine`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Trainer {
    /// First-order default — robust to the stochastic log-det gradient.
    Adam,
    /// Quasi-Newton, the paper's §B choice (probe-conditioned objective
    /// is deterministic, so line searches are well-defined).
    Lbfgs,
}

/// Self-contained engine backed by `gp::lkgp` (no artifacts needed).
pub struct RustEngine {
    pub cfg: SolverCfg,
    pub adam: trainer::AdamCfg,
    pub lbfgs: trainer::LbfgsCfg,
    pub trainer: Trainer,
}

impl Default for RustEngine {
    fn default() -> Self {
        RustEngine {
            cfg: SolverCfg::default(),
            adam: trainer::AdamCfg { steps: 60, lr: 0.08, ..Default::default() },
            lbfgs: trainer::LbfgsCfg::default(),
            trainer: Trainer::Adam,
        }
    }
}

impl RustEngine {
    /// Paper-faithful configuration: L-BFGS on the MAP objective (§B).
    pub fn with_lbfgs() -> Self {
        RustEngine { trainer: Trainer::Lbfgs, ..Default::default() }
    }

    /// Engine with the given solver precision mode. `Precision::F32` keeps
    /// Kronecker-factor storage in single precision and wraps every CG
    /// solve in iterative refinement measured against the exact f64
    /// operator (docs/parallelism.md). Replicas forked from this engine's
    /// `session_cfg` inherit the mode, so a pool shard answers
    /// consistently whether the writer or a replica serves.
    pub fn with_precision(precision: crate::gp::Precision) -> Self {
        let mut eng = RustEngine::default();
        eng.cfg.precision = precision;
        eng
    }
}

impl Engine for RustEngine {
    fn fit(&mut self, theta0: &[f64], data: &Dataset, seed: u64) -> Result<Vec<f64>> {
        // The FitSession owns the probe set, the warm solve buffer and the
        // factored preconditioner: every optimizer step warm-starts from
        // the previous one and factors are rebuilt only when theta drifts
        // past the compatibility window (gp::operator::PrecondFactors).
        let mut session = FitSession::new(Arc::new(data.clone()), self.cfg.clone(), seed)?;
        let method = match self.trainer {
            Trainer::Adam => FitMethod::Adam(self.adam.clone()),
            Trainer::Lbfgs => FitMethod::Lbfgs(self.lbfgs.clone()),
        };
        Ok(session.fit(theta0, &method)?.theta)
    }

    fn predict_final(
        &mut self,
        theta: &[f64],
        data: &Dataset,
        xq: &Matrix,
    ) -> Result<Vec<(f64, f64)>> {
        Ok(self.predict_final_cached(theta, data, xq, None, None)?.preds)
    }

    fn predict_final_warm(
        &mut self,
        theta: &[f64],
        data: &Dataset,
        xq: &Matrix,
        warm: Option<&[f64]>,
    ) -> Result<PredictOutcome> {
        self.predict_final_cached(theta, data, xq, warm, None)
    }

    fn predict_final_cached(
        &mut self,
        theta: &[f64],
        data: &Dataset,
        xq: &Matrix,
        warm: Option<&[f64]>,
        precond: Option<Arc<PrecondFactors>>,
    ) -> Result<PredictOutcome> {
        // Zero-copy path onto the same core the session drives
        // (`predict_final_impl`): these borrowed-Dataset entry points are
        // hit per-request (engine-parity tests, warm-CG benches), so they
        // must not pay a Dataset clone to build a one-shot session.
        let mut cache = precond;
        let (preds, solves, cg) = crate::gp::lkgp::predict_final_impl(
            theta, data, xq, &self.cfg, warm, &mut cache,
        )?;
        let nm = data.n() * data.m();
        Ok(PredictOutcome {
            alpha: Some(solves[..nm].to_vec()),
            cross: Some(solves[nm..].to_vec()),
            preds,
            cg_iters: cg.iters_per_rhs.iter().sum(),
            cg_mvm_rows: cg.mvm_rows,
            precond: cache,
            escalations: cg.escalations,
            dense_fallbacks: cg.fallback_dense as usize,
        })
    }

    /// One session answers the whole batch: final-step queries share a
    /// single `[y, c_1..c_q]` solve and `MeanAtSteps` reuses its alpha.
    fn answer_batch(
        &mut self,
        theta: &[f64],
        data: &Arc<Dataset>,
        queries: &[Query],
        warm: Option<&[f64]>,
        precond: Option<Arc<PrecondFactors>>,
        path: Option<PathLineage>,
    ) -> Result<QueryOutcome> {
        let mut post = Posterior::new(data.clone(), theta.to_vec(), self.cfg.clone())
            .with_guess(warm.map(|g| g.to_vec()))
            .with_precond(precond)
            .with_path(path);
        let answers = post.answer_batch(queries)?;
        Ok(QueryOutcome {
            answers,
            alpha: post.alpha().map(|a| a.to_vec()),
            xq: post.cross_xq().cloned(),
            cross: post.cross_solves().map(|c| c.to_vec()),
            cg_iters: post.cg_iters(),
            cg_mvm_rows: post.cg_mvm_rows(),
            solves: post.solve_calls(),
            precond: post.precond(),
            escalations: post.escalations(),
            dense_fallbacks: post.dense_fallbacks(),
            pathwise_hits: post.pathwise_hits(),
            sample_mvms: post.sample_mvms(),
            path: post.path_state(),
        })
    }

    fn sample_curves(
        &mut self,
        theta: &[f64],
        data: &Dataset,
        xq: &Matrix,
        s: usize,
        seed: u64,
    ) -> Result<Vec<Matrix>> {
        // zero-copy onto the Matheron core (see predict_final_cached)
        let mut rng = Pcg64::new(seed);
        let mut cache = None;
        let (samples, _cg) = crate::gp::lkgp::posterior_samples_impl(
            theta, data, xq, s, &self.cfg, &mut rng, &mut cache,
        )?;
        Ok(samples)
    }

    fn predict_mean(&mut self, theta: &[f64], data: &Dataset, xq: &Matrix) -> Result<Matrix> {
        let steps: Vec<usize> = (0..data.m()).collect();
        let mut post = Posterior::new(Arc::new(data.clone()), theta.to_vec(), self.cfg.clone());
        match post.answer(&Query::MeanAtSteps { xq: xq.clone(), steps })? {
            Answer::Steps(mat) => Ok(mat),
            _ => unreachable!("MeanAtSteps answers Steps"),
        }
    }

    fn session_cfg(&self) -> Option<SolverCfg> {
        Some(self.cfg.clone())
    }

    fn name(&self) -> &'static str {
        "rust"
    }
}

// ---------------------------------------------------------------------------
// XLA artifact engine (requires the vendored `xla` crate)

/// Engine that executes the AOT-compiled HLO artifacts on the PJRT CPU
/// client. Executables are compiled lazily and cached per artifact file.
#[cfg(feature = "xla")]
pub struct XlaEngine {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

// SAFETY: the xla crate wraps PJRT handles in Rc + raw pointers, which are
// !Send by default. XlaEngine owns the *only* clones of those Rcs (the
// client and every cached executable live inside this struct and are never
// handed out), so moving the whole engine into the prediction-service
// thread transfers all of them together; there is never concurrent or
// cross-thread shared access. The PJRT CPU client itself is thread-safe
// for compile/execute.
#[cfg(feature = "xla")]
unsafe impl Send for XlaEngine {}

#[cfg(feature = "xla")]
impl XlaEngine {
    /// Load the manifest and create the PJRT CPU client.
    pub fn load(artifacts_dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(XlaEngine {
            client,
            manifest,
            cache: HashMap::new(),
        })
    }

    /// Default artifacts directory (repo-relative, overridable by env).
    pub fn default_dir() -> std::path::PathBuf {
        artifacts_dir()
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn executable(&mut self, spec: &ArtifactSpec) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(&spec.file) {
            let path = self.manifest.path_of(spec);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| LkgpError::Manifest("bad path".into()))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            self.cache.insert(spec.file.clone(), exe);
        }
        Ok(&self.cache[&spec.file])
    }

    /// Execute an artifact with f64 inputs; returns each tuple output
    /// flattened to a Vec<f64>.
    fn exec(&mut self, spec: &ArtifactSpec, inputs: &[(Vec<usize>, Vec<f64>)]) -> Result<Vec<Vec<f64>>> {
        let exe = self.executable(spec)?;
        let mut lits = Vec::with_capacity(inputs.len());
        for (shape, data) in inputs {
            let dims: Vec<i64> = shape.iter().map(|&x| x as i64).collect();
            let expected: usize = shape.iter().product();
            debug_assert_eq!(expected, data.len(), "input buffer mismatch");
            lits.push(xla::Literal::vec1(data).reshape(&dims)?);
        }
        let mut result = exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
        let outs = result.decompose_tuple()?;
        let mut vecs = Vec::with_capacity(outs.len());
        for o in outs {
            vecs.push(o.to_vec::<f64>()?);
        }
        Ok(vecs)
    }

    /// Pad a dataset + theta to the bucket shape; returns flattened inputs
    /// shared by all entry points (theta, x, t, y, mask).
    fn padded_core(
        spec: &ArtifactSpec,
        theta: &[f64],
        data: &Dataset,
    ) -> (Vec<f64>, Vec<f64>, Vec<f64>, Vec<f64>, Vec<f64>) {
        let (bn, bm) = (spec.n, spec.m);
        let (n, m, d) = (data.n(), data.m(), data.d());
        debug_assert_eq!(d, spec.d);
        let mut x = vec![0.5; bn * d];
        for i in 0..n {
            x[i * d..(i + 1) * d].copy_from_slice(data.x.row(i));
        }
        // Extend the grid linearly beyond the data's range; padded columns
        // are masked out so the values only need to be finite/distinct.
        let mut t = vec![0.0; bm];
        t[..m].copy_from_slice(&data.t);
        let step = if m > 1 { data.t[m - 1] - data.t[m - 2] } else { 1.0 };
        for j in m..bm {
            t[j] = data.t[m - 1] + step.max(1e-3) * (j - m + 1) as f64;
        }
        let mut y = vec![0.0; bn * bm];
        let mut mask = vec![0.0; bn * bm];
        for i in 0..n {
            for j in 0..m {
                y[i * bm + j] = data.y[(i, j)];
                mask[i * bm + j] = data.mask[(i, j)];
            }
        }
        (theta.to_vec(), x, t, y, mask)
    }

    fn pad_queries(spec: &ArtifactSpec, xq: &Matrix) -> Vec<f64> {
        let d = spec.d;
        let mut out = vec![0.5; spec.q * d];
        for i in 0..xq.rows().min(spec.q) {
            out[i * d..(i + 1) * d].copy_from_slice(xq.row(i));
        }
        // replicate the first query into unused slots (harmless)
        if xq.rows() > 0 {
            for i in xq.rows()..spec.q {
                let src: Vec<f64> = xq.row(0).to_vec();
                out[i * d..(i + 1) * d].copy_from_slice(&src);
            }
        }
        out
    }

    /// One masked-Kronecker MVM through the artifact (tests/benches).
    pub fn mvm(&mut self, theta: &[f64], data: &Dataset, v: &Matrix) -> Result<Matrix> {
        let spec = self
            .manifest
            .pick("mvm", data.n(), data.m(), data.d())?
            .clone();
        let (bn, bm) = (spec.n, spec.m);
        let (th, x, t, _y, mask) = Self::padded_core(&spec, theta, data);
        let mut vp = vec![0.0; bn * bm];
        for i in 0..data.n() {
            for j in 0..data.m() {
                vp[i * bm + j] = v[(i, j)];
            }
        }
        let d = data.d();
        let outs = self.exec(
            &spec,
            &[
                (vec![d + 3], th),
                (vec![bn, d], x),
                (vec![bm], t),
                (vec![bn, bm], mask),
                (vec![bn, bm], vp),
            ],
        )?;
        let mut out = Matrix::zeros(data.n(), data.m());
        for i in 0..data.n() {
            for j in 0..data.m() {
                out[(i, j)] = outs[0][i * bm + j];
            }
        }
        Ok(out)
    }

    /// MAP objective value + gradient via the `mll_grad` artifact.
    /// Returns (value, grad, cg_iterations).
    pub fn mll_grad(
        &mut self,
        theta: &[f64],
        data: &Dataset,
        seed: u64,
    ) -> Result<(f64, Vec<f64>, usize)> {
        let spec = self
            .manifest
            .pick("mll_grad", data.n(), data.m(), data.d())?
            .clone();
        let (bn, bm, p) = (spec.n, spec.m, spec.p);
        let (th, x, t, y, mask) = Self::padded_core(&spec, theta, data);
        let mut rng = Pcg64::new(seed);
        let probes = rng.rademacher_vec(p * bn * bm);
        let d = data.d();
        let outs = self.exec(
            &spec,
            &[
                (vec![d + 3], th),
                (vec![bn, d], x),
                (vec![bm], t),
                (vec![bn, bm], y),
                (vec![bn, bm], mask),
                (vec![p, bn, bm], probes),
            ],
        )?;
        Ok((outs[0][0], outs[1].clone(), outs[2][0] as usize))
    }
}

#[cfg(feature = "xla")]
impl Engine for XlaEngine {
    fn fit(&mut self, theta0: &[f64], data: &Dataset, seed: u64) -> Result<Vec<f64>> {
        let spec = self
            .manifest
            .pick("fit_adam", data.n(), data.m(), data.d())?
            .clone();
        let (bn, bm, p) = (spec.n, spec.m, spec.p);
        let (th, x, t, y, mask) = Self::padded_core(&spec, theta0, data);
        let mut rng = Pcg64::new(seed);
        let probes = rng.rademacher_vec(p * bn * bm);
        let d = data.d();
        let outs = self.exec(
            &spec,
            &[
                (vec![d + 3], th),
                (vec![bn, d], x),
                (vec![bm], t),
                (vec![bn, bm], y),
                (vec![bn, bm], mask),
                (vec![p, bn, bm], probes),
            ],
        )?;
        Ok(outs[0].clone())
    }

    fn predict_final(
        &mut self,
        theta: &[f64],
        data: &Dataset,
        xq: &Matrix,
    ) -> Result<Vec<(f64, f64)>> {
        // Moments from Matheron samples (the posterior artifact); the rust
        // engine provides the exact per-query variance alternative.
        let q = xq.rows();
        let spec = self
            .manifest
            .pick("posterior", data.n(), data.m(), data.d())?
            .clone();
        if q > spec.q {
            // chunk queries through the bucket
            let mut out = Vec::with_capacity(q);
            let mut start = 0;
            while start < q {
                let end = (start + spec.q).min(q);
                let mut chunk = Matrix::zeros(end - start, xq.cols());
                for i in start..end {
                    chunk.row_mut(i - start).copy_from_slice(xq.row(i));
                }
                out.extend(self.predict_final(theta, data, &chunk)?);
                start = end;
            }
            return Ok(out);
        }
        let s = spec.s.max(32);
        let samples = self.sample_curves(theta, data, xq, s, 7_777)?;
        let m = data.m();
        let n = data.n();
        let mut out = Vec::with_capacity(q);
        let theta_u = Theta::unpack(theta);
        for qi in 0..q {
            let vals: Vec<f64> = samples.iter().map(|smp| smp[(n + qi, m - 1)]).collect();
            let (mean, _) = crate::metrics::mean_stderr(&vals);
            let var = vals.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>()
                / (vals.len().max(2) - 1) as f64;
            out.push((mean, var + theta_u.sigma2));
        }
        Ok(out)
    }

    fn sample_curves(
        &mut self,
        theta: &[f64],
        data: &Dataset,
        xq: &Matrix,
        s: usize,
        seed: u64,
    ) -> Result<Vec<Matrix>> {
        let spec = self
            .manifest
            .pick("posterior", data.n(), data.m(), data.d())?
            .clone();
        let (bn, bm, bq, bs) = (spec.n, spec.m, spec.q, spec.s);
        if xq.rows() > bq {
            return Err(LkgpError::Shape(format!(
                "query count {} exceeds bucket q={bq}",
                xq.rows()
            )));
        }
        let (th, x, t, y, mask) = Self::padded_core(&spec, theta, data);
        let xqp = Self::pad_queries(&spec, xq);
        let mut rng = Pcg64::new(seed);
        let d = data.d();
        let (n, m) = (data.n(), data.m());
        let mut out: Vec<Matrix> = Vec::with_capacity(s);
        // The artifact draws bs samples per execution; run ceil(s/bs) times.
        while out.len() < s {
            let zeta = rng.normal_vec(bs * (bn + bq) * bm);
            let eps = rng.normal_vec(bs * bn * bm);
            let outs = self.exec(
                &spec,
                &[
                    (vec![d + 3], th.clone()),
                    (vec![bn, d], x.clone()),
                    (vec![bm], t.clone()),
                    (vec![bn, bm], y.clone()),
                    (vec![bn, bm], mask.clone()),
                    (vec![bq, d], xqp.clone()),
                    (vec![bs, bn + bq, bm], zeta),
                    (vec![bs, bn, bm], eps),
                ],
            )?;
            let samples = &outs[0];
            for si in 0..bs {
                if out.len() >= s {
                    break;
                }
                // slice train rows [0, n) and query rows [bn, bn + q)
                let mut smp = Matrix::zeros(n + xq.rows(), m);
                for i in 0..n {
                    for j in 0..m {
                        smp[(i, j)] = samples[si * (bn + bq) * bm + i * bm + j];
                    }
                }
                for qi in 0..xq.rows() {
                    for j in 0..m {
                        smp[(n + qi, j)] = samples[si * (bn + bq) * bm + (bn + qi) * bm + j];
                    }
                }
                out.push(smp);
            }
        }
        Ok(out)
    }

    fn predict_mean(&mut self, theta: &[f64], data: &Dataset, xq: &Matrix) -> Result<Matrix> {
        let spec = self
            .manifest
            .pick("predict_mean", data.n(), data.m(), data.d())?
            .clone();
        let (bn, bm, bq) = (spec.n, spec.m, spec.q);
        let q = xq.rows();
        if q > bq {
            return Err(LkgpError::Shape(format!("query count {q} exceeds bucket q={bq}")));
        }
        let (th, x, t, y, mask) = Self::padded_core(&spec, theta, data);
        let xqp = Self::pad_queries(&spec, xq);
        let d = data.d();
        let outs = self.exec(
            &spec,
            &[
                (vec![d + 3], th),
                (vec![bn, d], x),
                (vec![bm], t),
                (vec![bn, bm], y),
                (vec![bn, bm], mask),
                (vec![bq, d], xqp),
            ],
        )?;
        let mut out = Matrix::zeros(q, data.m());
        for qi in 0..q {
            for j in 0..data.m() {
                out[(qi, j)] = outs[0][qi * bm + j];
            }
        }
        Ok(out)
    }

    fn name(&self) -> &'static str {
        "xla"
    }
}

/// Open the configured engine: XLA artifacts when requested and available
/// (feature `xla`), rust fallback otherwise.
pub fn open_engine(prefer_xla: bool) -> Box<dyn Engine> {
    #[cfg(feature = "xla")]
    if prefer_xla {
        match XlaEngine::load(&artifacts_dir()) {
            Ok(e) => return Box::new(e),
            Err(err) => {
                eprintln!("lkgp: falling back to rust engine: {err}");
            }
        }
    }
    #[cfg(not(feature = "xla"))]
    let _ = prefer_xla;
    Box::<RustEngine>::default()
}
