//! Artifact manifest: the build-time contract between `python/compile/aot.py`
//! and the rust runtime.
//!
//! `artifacts/manifest.json` lists every exported HLO module with its shape
//! bucket (n, m, d, q, s, p) and input/output specs. The runtime picks the
//! smallest bucket that fits a live problem and pads (see `runtime::engine`).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::error::{LkgpError, Result};
use crate::json::Json;

/// One exported HLO module.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    /// Entry-point name: mvm | kernel_matrices | mll_grad | fit_adam |
    /// predict_mean | posterior.
    pub entry: String,
    /// File name inside the artifacts directory.
    pub file: String,
    /// Bucket shape.
    pub n: usize,
    pub m: usize,
    pub d: usize,
    /// Query configs (predict/posterior entries).
    pub q: usize,
    /// Posterior samples per call.
    pub s: usize,
    /// Probe count (mll/fit entries).
    pub p: usize,
    /// Input names and shapes, in call order.
    pub inputs: Vec<(String, Vec<usize>)>,
    /// Output names, in tuple order.
    pub outputs: Vec<String>,
    /// Adam steps baked into fit_adam graphs (0 otherwise).
    pub steps: usize,
}

/// Parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactSpec>,
    pub fit_steps: usize,
    pub fit_lr: f64,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            LkgpError::Manifest(format!("cannot read {}: {e}", path.display()))
        })?;
        let doc = Json::parse(&text)?;
        if doc.get("format").and_then(Json::as_usize) != Some(1) {
            return Err(LkgpError::Manifest("unsupported manifest format".into()));
        }
        let mut artifacts = Vec::new();
        for rec in doc
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| LkgpError::Manifest("missing artifacts".into()))?
        {
            let geti = |k: &str| rec.get(k).and_then(Json::as_usize).unwrap_or(0);
            let mut inputs = Vec::new();
            for inp in rec.get("inputs").and_then(Json::as_arr).unwrap_or(&[]) {
                let name = inp
                    .get("name")
                    .and_then(Json::as_str)
                    .unwrap_or("")
                    .to_string();
                let shape: Vec<usize> = inp
                    .get("shape")
                    .and_then(Json::as_arr)
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(Json::as_usize)
                    .collect();
                inputs.push((name, shape));
            }
            let outputs: Vec<String> = rec
                .get("outputs")
                .and_then(Json::as_arr)
                .unwrap_or(&[])
                .iter()
                .filter_map(|o| o.as_str().map(str::to_string))
                .collect();
            artifacts.push(ArtifactSpec {
                entry: rec
                    .get("entry")
                    .and_then(Json::as_str)
                    .ok_or_else(|| LkgpError::Manifest("artifact missing entry".into()))?
                    .to_string(),
                file: rec
                    .get("file")
                    .and_then(Json::as_str)
                    .ok_or_else(|| LkgpError::Manifest("artifact missing file".into()))?
                    .to_string(),
                n: geti("n"),
                m: geti("m"),
                d: geti("d"),
                q: geti("q"),
                s: geti("s"),
                p: geti("p"),
                inputs,
                outputs,
                steps: geti("steps"),
            });
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            artifacts,
            fit_steps: doc.get("fit_steps").and_then(Json::as_usize).unwrap_or(0),
            fit_lr: doc
                .get("fit_lr")
                .and_then(Json::as_f64)
                .unwrap_or(0.05),
        })
    }

    /// Smallest bucket of `entry` that fits (n, m, d): bucket.n >= n,
    /// bucket.m >= m, bucket.d == d (dimensions can't be padded — the
    /// kernel's ARD lengthscales are per-dimension).
    pub fn pick(&self, entry: &str, n: usize, m: usize, d: usize) -> Result<&ArtifactSpec> {
        self.artifacts
            .iter()
            .filter(|a| a.entry == entry && a.n >= n && a.m >= m && a.d == d)
            .min_by_key(|a| (a.n, a.m))
            .ok_or(LkgpError::NoBucket { n, m, d })
    }

    /// All distinct buckets (for diagnostics / tests).
    pub fn buckets(&self) -> Vec<(usize, usize, usize)> {
        let mut set: BTreeMap<(usize, usize, usize), ()> = BTreeMap::new();
        for a in &self.artifacts {
            set.insert((a.n, a.m, a.d), ());
        }
        set.into_keys().collect()
    }

    /// Absolute path of an artifact file.
    pub fn path_of(&self, spec: &ArtifactSpec) -> PathBuf {
        self.dir.join(&spec.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_manifest(dir: &Path) {
        let doc = r#"{"format": 1, "dtype": "f64", "fit_steps": 100, "fit_lr": 0.05,
          "artifacts": [
            {"entry": "mvm", "file": "mvm_n16_m16_d3.hlo.txt", "n": 16, "m": 16,
             "d": 3, "q": 8, "s": 16, "p": 8, "inputs": [], "outputs": ["out"]},
            {"entry": "mvm", "file": "mvm_n32_m16_d3.hlo.txt", "n": 32, "m": 16,
             "d": 3, "q": 8, "s": 16, "p": 8, "inputs": [], "outputs": ["out"]},
            {"entry": "mvm", "file": "mvm_n16_m52_d7.hlo.txt", "n": 16, "m": 52,
             "d": 7, "q": 8, "s": 16, "p": 8, "inputs": [], "outputs": ["out"]}
        ]}"#;
        std::fs::write(dir.join("manifest.json"), doc).unwrap();
    }

    #[test]
    fn loads_and_picks_smallest_fitting_bucket() {
        let dir = std::env::temp_dir().join("lkgp_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        fake_manifest(&dir);
        let man = Manifest::load(&dir).unwrap();
        assert_eq!(man.artifacts.len(), 3);
        assert_eq!(man.fit_steps, 100);
        let b = man.pick("mvm", 10, 12, 3).unwrap();
        assert_eq!((b.n, b.m), (16, 16));
        let b2 = man.pick("mvm", 20, 16, 3).unwrap();
        assert_eq!((b2.n, b2.m), (32, 16));
        assert!(man.pick("mvm", 64, 16, 3).is_err());
        assert!(man.pick("mvm", 8, 8, 5).is_err()); // d mismatch
        assert_eq!(man.buckets().len(), 3);
    }

    #[test]
    fn real_manifest_parses_when_built() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return; // artifacts not built in this environment
        }
        let man = Manifest::load(&dir).unwrap();
        assert!(!man.artifacts.is_empty());
        // the LCBench quality bucket must exist
        assert!(man.pick("mll_grad", 16, 52, 7).is_ok());
        for a in &man.artifacts {
            assert!(man.path_of(a).exists(), "{}", a.file);
        }
    }
}
