//! Deterministic chaos harness: seeded fault injection for the serving
//! stack (docs/robustness.md).
//!
//! Two wrappers sit at the stack's natural seams:
//!
//! * [`ChaosEngine`] wraps a [`RustEngine`] and, per engine call, may
//!   panic (exercising the pool's catch-unwind recovery and the shard
//!   circuit breaker), force divergence by capping the CG budget at one
//!   iteration (exercising the escalation ladder in
//!   `gp::lkgp::solve_healthy`), or sleep (exercising deadlines).
//! * [`ChaosCorpus`] wraps a [`Corpus`] and may fail task
//!   materialization with an I/O error (exercising per-task isolation
//!   and quarantine re-materialization probes) or poison a curve value
//!   with NaN (exercising non-finite detection: the solve must surface a
//!   typed `LkgpError::Solver`, never a silent NaN answer).
//!
//! Every fault is drawn from a seeded [`Pcg64`], so a given
//! [`FaultPlan`] replays the same fault sequence per call stream. Fault
//! draws are per-wrapper; under a multi-worker pool the interleaving of
//! *calls* is scheduling-dependent, but the invariants the chaos soak
//! asserts (every request resolves to an answer or a typed error; no
//! non-finite answer ever escapes) hold for any interleaving.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::error::Result;
use crate::gp::lkgp::{Dataset, SolverCfg};
use crate::gp::operator::PrecondFactors;
use crate::gp::pathwise::PathLineage;
use crate::gp::session::Query;
use crate::json::Json;
use crate::lcbench::corpus::{Corpus, TaskMeta};
use crate::lcbench::Task;
use crate::linalg::Matrix;
use crate::rng::Pcg64;

use super::{Engine, PredictOutcome, QueryOutcome, RustEngine};

/// Seeded fault-injection plan shared by [`ChaosEngine`] and
/// [`ChaosCorpus`]. All rates are probabilities in `[0, 1]` drawn
/// independently per call; the default plan injects nothing.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultPlan {
    /// Base RNG seed (each wrapper forks it with its own salt).
    pub seed: u64,
    /// Probability an engine call panics before doing any work.
    pub panic_rate: f64,
    /// Probability an engine call runs with the CG iteration budget
    /// forced to 1, so the solve cannot converge at ladder rung 0.
    pub diverge_rate: f64,
    /// Probability an engine call sleeps [`FaultPlan::slow_ms`] first.
    pub slow_rate: f64,
    /// Sleep duration for slow faults, in milliseconds.
    pub slow_ms: u64,
    /// Probability a corpus task materialization fails with an I/O error.
    pub io_rate: f64,
    /// Probability a materialized task has one curve value poisoned NaN.
    pub nan_rate: f64,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            seed: 0,
            panic_rate: 0.0,
            diverge_rate: 0.0,
            slow_rate: 0.0,
            slow_ms: 20,
            io_rate: 0.0,
            nan_rate: 0.0,
        }
    }
}

fn parse_rate(v: &str) -> Option<f64> {
    let r: f64 = v.trim().parse().ok()?;
    (0.0..=1.0).contains(&r).then_some(r)
}

impl FaultPlan {
    /// Parse a `key=value` comma list, e.g.
    /// `"panic=0.05,diverge=0.2,slow=0.1,slow_ms=15,io=0.02,nan=0.01,seed=7"`.
    /// Unknown keys and out-of-range rates yield `None`.
    pub fn parse(spec: &str) -> Option<FaultPlan> {
        let mut plan = FaultPlan::default();
        for part in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            let (k, v) = part.split_once('=')?;
            match k.trim() {
                "seed" => plan.seed = v.trim().parse().ok()?,
                "panic" => plan.panic_rate = parse_rate(v)?,
                "diverge" => plan.diverge_rate = parse_rate(v)?,
                "slow" => plan.slow_rate = parse_rate(v)?,
                "slow_ms" | "slow-ms" => plan.slow_ms = v.trim().parse().ok()?,
                "io" => plan.io_rate = parse_rate(v)?,
                "nan" => plan.nan_rate = parse_rate(v)?,
                _ => return None,
            }
        }
        Some(plan)
    }

    /// Whether any engine-side fault can fire.
    pub fn engine_faults(&self) -> bool {
        self.panic_rate > 0.0 || self.diverge_rate > 0.0 || self.slow_rate > 0.0
    }

    /// Whether any corpus-side fault can fire.
    pub fn corpus_faults(&self) -> bool {
        self.io_rate > 0.0 || self.nan_rate > 0.0
    }
}

/// Shared tally of injected faults, for run reports and the chaos soak's
/// sanity checks (a soak that injected nothing proved nothing).
#[derive(Default)]
pub struct ChaosStats {
    pub panics: AtomicU64,
    pub diverges: AtomicU64,
    pub slows: AtomicU64,
    pub io_errors: AtomicU64,
    pub nans: AtomicU64,
}

impl ChaosStats {
    /// Total faults injected across all kinds.
    pub fn total(&self) -> u64 {
        self.panics.load(Ordering::Relaxed)
            + self.diverges.load(Ordering::Relaxed)
            + self.slows.load(Ordering::Relaxed)
            + self.io_errors.load(Ordering::Relaxed)
            + self.nans.load(Ordering::Relaxed)
    }
}

/// Fault-injecting wrapper around the pure-rust engine. See the module
/// docs for which faults exercise which recovery layer.
pub struct ChaosEngine {
    inner: RustEngine,
    plan: FaultPlan,
    rng: Pcg64,
    stats: Arc<ChaosStats>,
}

impl ChaosEngine {
    /// Wrap `inner`; `salt` decorrelates the fault stream per wrapper
    /// (e.g. the shard id), keeping multi-shard runs deterministic
    /// per shard instead of sharing one global draw sequence.
    pub fn new(inner: RustEngine, plan: FaultPlan, salt: u64, stats: Arc<ChaosStats>) -> Self {
        let mut rng = Pcg64::new(plan.seed ^ 0x9e37_79b9_7f4a_7c15);
        let rng = rng.fork(salt);
        ChaosEngine { inner, plan, rng, stats }
    }

    /// Draw this call's faults: maybe sleep, maybe panic, and return
    /// whether the call must run with a divergent (1-iteration) CG
    /// budget.
    fn roll(&mut self) -> bool {
        if self.plan.slow_rate > 0.0 && self.rng.uniform() < self.plan.slow_rate {
            self.stats.slows.fetch_add(1, Ordering::Relaxed);
            std::thread::sleep(Duration::from_millis(self.plan.slow_ms));
        }
        if self.plan.panic_rate > 0.0 && self.rng.uniform() < self.plan.panic_rate {
            self.stats.panics.fetch_add(1, Ordering::Relaxed);
            panic!("chaos: injected engine panic");
        }
        let diverge = self.plan.diverge_rate > 0.0 && self.rng.uniform() < self.plan.diverge_rate;
        if diverge {
            self.stats.diverges.fetch_add(1, Ordering::Relaxed);
        }
        diverge
    }

    /// Run `f` against the inner engine, with the CG budget capped at one
    /// iteration when `diverge` is set (restored afterwards). The capped
    /// solve cannot converge at escalation rung 0, so a correct ladder
    /// still returns converged answers — with `escalations > 0`.
    fn with_budget<T>(&mut self, diverge: bool, f: impl FnOnce(&mut RustEngine) -> T) -> T {
        if !diverge {
            return f(&mut self.inner);
        }
        let saved = self.inner.cfg.cg_max_iters;
        self.inner.cfg.cg_max_iters = 1;
        let out = f(&mut self.inner);
        self.inner.cfg.cg_max_iters = saved;
        out
    }
}

impl Engine for ChaosEngine {
    fn fit(&mut self, theta0: &[f64], data: &Dataset, seed: u64) -> Result<Vec<f64>> {
        let diverge = self.roll();
        self.with_budget(diverge, |e| e.fit(theta0, data, seed))
    }

    fn predict_final(
        &mut self,
        theta: &[f64],
        data: &Dataset,
        xq: &Matrix,
    ) -> Result<Vec<(f64, f64)>> {
        let diverge = self.roll();
        self.with_budget(diverge, |e| e.predict_final(theta, data, xq))
    }

    fn predict_final_warm(
        &mut self,
        theta: &[f64],
        data: &Dataset,
        xq: &Matrix,
        warm: Option<&[f64]>,
    ) -> Result<PredictOutcome> {
        let diverge = self.roll();
        self.with_budget(diverge, |e| e.predict_final_warm(theta, data, xq, warm))
    }

    fn predict_final_cached(
        &mut self,
        theta: &[f64],
        data: &Dataset,
        xq: &Matrix,
        warm: Option<&[f64]>,
        precond: Option<Arc<PrecondFactors>>,
    ) -> Result<PredictOutcome> {
        let diverge = self.roll();
        self.with_budget(diverge, |e| {
            e.predict_final_cached(theta, data, xq, warm, precond)
        })
    }

    fn answer_batch(
        &mut self,
        theta: &[f64],
        data: &Arc<Dataset>,
        queries: &[Query],
        warm: Option<&[f64]>,
        precond: Option<Arc<PrecondFactors>>,
        path: Option<PathLineage>,
    ) -> Result<QueryOutcome> {
        let diverge = self.roll();
        self.with_budget(diverge, |e| {
            e.answer_batch(theta, data, queries, warm, precond, path)
        })
    }

    fn sample_curves(
        &mut self,
        theta: &[f64],
        data: &Dataset,
        xq: &Matrix,
        s: usize,
        seed: u64,
    ) -> Result<Vec<Matrix>> {
        let diverge = self.roll();
        self.with_budget(diverge, |e| e.sample_curves(theta, data, xq, s, seed))
    }

    fn predict_mean(&mut self, theta: &[f64], data: &Dataset, xq: &Matrix) -> Result<Matrix> {
        let diverge = self.roll();
        self.with_budget(diverge, |e| e.predict_mean(theta, data, xq))
    }

    fn session_cfg(&self) -> Option<SolverCfg> {
        // Replicas fork from the *healthy* config: chaos exercises the
        // writer path; read replicas answering bit-identically alongside a
        // faulting writer is exactly the isolation the soak asserts.
        self.inner.session_cfg()
    }

    fn name(&self) -> &'static str {
        "chaos"
    }
}

/// Fault-injecting wrapper around a corpus: I/O errors on task
/// materialization and NaN poisoning of curve data, both seeded.
pub struct ChaosCorpus {
    inner: Arc<dyn Corpus>,
    plan: FaultPlan,
    rng: Mutex<Pcg64>,
    stats: Arc<ChaosStats>,
}

impl ChaosCorpus {
    pub fn new(inner: Arc<dyn Corpus>, plan: FaultPlan, stats: Arc<ChaosStats>) -> Self {
        let rng = Mutex::new(Pcg64::new(plan.seed ^ 0x85eb_ca77_c2b2_ae63));
        ChaosCorpus { inner, plan, rng, stats }
    }
}

impl Corpus for ChaosCorpus {
    fn len(&self) -> usize {
        self.inner.len()
    }

    fn name(&self) -> String {
        format!("chaos({})", self.inner.name())
    }

    fn fingerprint(&self) -> String {
        // Distinct from the inner corpus: NaN poisoning means served data
        // may differ, and a recorded trace must not falsely pin the clean
        // corpus.
        format!("chaos-{}", self.inner.fingerprint())
    }

    fn trace_pin(&self) -> Vec<(String, Json)> {
        self.inner.trace_pin()
    }

    fn task(&self, id: usize) -> crate::Result<Arc<Task>> {
        let (io, nan) = {
            let mut rng = self.rng.lock().unwrap_or_else(|p| p.into_inner());
            (
                self.plan.io_rate > 0.0 && rng.uniform() < self.plan.io_rate,
                self.plan.nan_rate > 0.0 && rng.uniform() < self.plan.nan_rate,
            )
        };
        if io {
            self.stats.io_errors.fetch_add(1, Ordering::Relaxed);
            return Err(crate::LkgpError::Io(std::io::Error::new(
                std::io::ErrorKind::Other,
                format!("chaos: injected i/o failure materializing task {id}"),
            )));
        }
        let task = self.inner.task(id)?;
        if nan {
            self.stats.nans.fetch_add(1, Ordering::Relaxed);
            let mut poisoned = (*task).clone();
            // One observed value is enough: any NaN reaching a solve must
            // surface as a typed non-finite Solver error downstream.
            poisoned.curves[(0, 0)] = f64::NAN;
            return Ok(Arc::new(poisoned));
        }
        Ok(task)
    }

    fn meta(&self, id: usize) -> crate::Result<TaskMeta> {
        // Metadata reads stay fault-free (they never feed a solve).
        self.inner.meta(id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::session::Answer;

    #[test]
    fn fault_plan_parses_and_rejects() {
        let p = FaultPlan::parse("panic=0.5,diverge=1,slow=0.25,slow_ms=5,io=0.1,nan=0.2,seed=9")
            .unwrap();
        assert_eq!(p.seed, 9);
        assert_eq!(p.panic_rate, 0.5);
        assert_eq!(p.diverge_rate, 1.0);
        assert_eq!(p.slow_rate, 0.25);
        assert_eq!(p.slow_ms, 5);
        assert_eq!(p.io_rate, 0.1);
        assert_eq!(p.nan_rate, 0.2);
        assert_eq!(FaultPlan::parse("").unwrap(), FaultPlan::default());
        assert!(FaultPlan::parse("panic=1.5").is_none(), "rate out of range");
        assert!(FaultPlan::parse("bogus=1").is_none(), "unknown key");
        assert!(FaultPlan::parse("panic").is_none(), "missing value");
    }

    #[test]
    fn chaos_corpus_injects_io_and_nan_deterministically() {
        use crate::lcbench::corpus::SimCorpus;
        let stats = Arc::new(ChaosStats::default());
        let plan = FaultPlan { io_rate: 1.0, ..Default::default() };
        let corpus = ChaosCorpus::new(
            Arc::new(SimCorpus::new(2, 4, 0)),
            plan,
            stats.clone(),
        );
        assert!(corpus.task(0).is_err());
        assert_eq!(stats.io_errors.load(Ordering::Relaxed), 1);
        // metadata path bypasses fault injection entirely
        assert!(corpus.meta(0).is_ok());

        let stats = Arc::new(ChaosStats::default());
        let plan = FaultPlan { nan_rate: 1.0, ..Default::default() };
        let corpus = ChaosCorpus::new(
            Arc::new(SimCorpus::new(2, 4, 0)),
            plan,
            stats.clone(),
        );
        let task = corpus.task(0).unwrap();
        assert!(task.curves[(0, 0)].is_nan());
        assert_eq!(stats.nans.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn chaos_engine_with_zero_rates_is_the_inner_engine() {
        use crate::gp::Theta;
        let data = crate::lcbench::toy_dataset(5, 6, 2, 3);
        let xq = Matrix::from_vec(1, data.d(), vec![0.5; data.d()]);
        let theta = Theta::default_packed(data.d());

        let mut plain = RustEngine::default();
        let want = plain.predict_final(&theta, &data, &xq).unwrap();

        let stats = Arc::new(ChaosStats::default());
        let mut chaotic = ChaosEngine::new(
            RustEngine::default(),
            FaultPlan::default(),
            0,
            stats.clone(),
        );
        let got = chaotic.predict_final(&theta, &data, &xq).unwrap();
        assert_eq!(want.len(), got.len());
        for (a, b) in want.iter().zip(&got) {
            assert_eq!(a.0.to_bits(), b.0.to_bits(), "chaos-off mean must be bit-identical");
            assert_eq!(a.1.to_bits(), b.1.to_bits(), "chaos-off var must be bit-identical");
        }
        assert_eq!(stats.total(), 0);
    }

    #[test]
    fn forced_divergence_recovers_through_the_ladder() {
        use crate::gp::Theta;
        let data = Arc::new(crate::lcbench::toy_dataset(5, 6, 2, 5));
        let xq = Matrix::from_vec(1, data.d(), vec![0.4; data.d()]);
        let theta = Theta::default_packed(data.d());
        let queries = vec![Query::MeanAtFinal { xq }];

        let stats = Arc::new(ChaosStats::default());
        let plan = FaultPlan { diverge_rate: 1.0, ..Default::default() };
        let mut chaotic =
            ChaosEngine::new(RustEngine::default(), plan, 0, stats.clone());
        let out = chaotic
            .answer_batch(&theta, &data, &queries, None, None, None)
            .expect("ladder must recover a 1-iteration CG budget");
        assert!(stats.diverges.load(Ordering::Relaxed) >= 1);
        assert!(out.escalations > 0, "recovery must be visible as escalations");
        match &out.answers[0] {
            Answer::Final(preds) => {
                for (mu, var) in preds {
                    assert!(mu.is_finite() && var.is_finite() && *var > 0.0);
                }
            }
            other => panic!("unexpected answer {other:?}"),
        }
        // budget restored after the call
        assert_eq!(chaotic.inner.cfg.cg_max_iters, SolverCfg::default().cg_max_iters);
    }
}
