//! `lkgp` CLI — leader entrypoint for the coordinator and utilities.
//!
//! Subcommands:
//!   serve      run the freeze-thaw AutoML coordinator on a simulated
//!              LCBench workload (see examples/automl_loop.rs for the
//!              library-level version)
//!   pool       run one coordinator per corpus task concurrently through
//!              the multi-task sharded ServicePool (see docs/serving.md).
//!              --corpus sim|DIR picks the data plane (simulator or a
//!              directory of LCBench-style JSON dumps, docs/data.md);
//!              --record FILE captures the live traffic as a replayable
//!              trace; --replay FILE [--concurrent] replays a trace and
//!              asserts zero errors + stats invariants (docs/ci.md);
//!              --deadline-ms N sheds expired work with typed Timeout
//!              errors and --chaos SPEC runs the pool under seeded fault
//!              injection (docs/robustness.md); --sample-storm runs the
//!              seeded Hyperband/ASHA Thompson-sampling storm instead
//!              (pathwise posterior draws served solve-free from cached
//!              lineage, with a STORM_CHECKSUM determinism receipt —
//!              docs/sampling.md); --buckets N|auto folds many tasks onto
//!              hash-routed shard buckets and --observe-storm drives
//!              steady epoch arrivals through warm Observe re-solves with
//!              --refit-every / --refit-drift tuning the refit policy
//!              (docs/serving.md)
//!   artifacts  print the artifact manifest and verify executables load
//!   smoke      end-to-end smoke: fit + predict on a toy problem
//!   lint       run the in-tree invariant linter over the crate's own
//!              sources (lock-order graph, unsafe audit, panic + float
//!              discipline, stats/bench drift; docs/static_analysis.md)
//!              and write the ANALYSIS.json inventory; exits non-zero on
//!              any unjustified finding
//!
//! Run `lkgp <cmd> --help`-ish by reading DESIGN.md; flags use
//! `--key value` / `--key=value` (see util::Args).
use lkgp::util::Args;

fn main() -> lkgp::Result<()> {
    let args = Args::from_env();
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "artifacts" => cmd_artifacts(&args),
        "smoke" => cmd_smoke(&args),
        "serve" => cmd_serve(&args),
        "pool" => lkgp::coordinator::serve_pool(&args),
        "lint" => cmd_lint(&args),
        _ => {
            eprintln!(
                "usage: lkgp <artifacts|smoke|serve|pool|lint> [--engine rust|xla] \
                 [--seed N] [--configs N] [--tasks N] [--workers N] [--warm on|off] \
                 [--replicas N] [--precond off|auto|rank=R] [--threads N] \
                 [--precision f64|f32] [--corpus sim|DIR] \
                 [--record FILE] [--replay FILE [--concurrent]] \
                 [--deadline-ms N] [--chaos panic=P,diverge=P,slow=P,io=P,nan=P,seed=N] \
                 [--sample-storm [--draws N] [--bursts N] [--eta N]] \
                 [--buckets N|auto] [--observe-storm] [--refit-every K] \
                 [--refit-drift X] [--root CRATE_DIR] [--json ANALYSIS_PATH]"
            );
            Ok(())
        }
    }
}

fn cmd_lint(args: &Args) -> lkgp::Result<()> {
    use lkgp::analysis::{analyze, AnalysisConfig, AnalysisInput};
    // Default to the crate that built this binary: `cargo run -- lint`
    // from anywhere lints the shipped tree.
    let root = std::path::PathBuf::from(
        args.get("root").unwrap_or(env!("CARGO_MANIFEST_DIR")),
    );
    let input = AnalysisInput::load(&root)?;
    let report = analyze(&input, &AnalysisConfig::crate_default());
    let json_path = match args.get("json") {
        Some(p) => std::path::PathBuf::from(p),
        // next to ci.sh, at the repo root above the crate
        None => root.join("..").join("ANALYSIS.json"),
    };
    std::fs::write(&json_path, report.to_json().pretty())?;
    println!(
        "lint: {} files, {} lock sites, {} lock edges, {} unsafe sites, {} pragmas",
        report.files_scanned,
        report.lock_sites.len(),
        report.lock_edges.len(),
        report.unsafe_sites.len(),
        report.pragmas.len(),
    );
    println!("lint: inventory written to {}", json_path.display());
    for f in &report.findings {
        if let Some(reason) = &f.justified {
            println!(
                "  allowed {}:{} [{}] — {}",
                f.file,
                f.line,
                f.rule.name(),
                reason
            );
        }
    }
    let bad = report.unjustified();
    for f in &bad {
        println!("FAIL {}:{} [{}] {}", f.file, f.line, f.rule.name(), f.message);
    }
    if bad.is_empty() {
        println!("LINT_OK");
        Ok(())
    } else {
        Err(lkgp::LkgpError::Lint { findings: bad.len() })
    }
}

fn cmd_artifacts(_args: &Args) -> lkgp::Result<()> {
    let dir = lkgp::runtime::artifacts_dir();
    let man = lkgp::runtime::Manifest::load(&dir)?;
    println!("artifacts dir: {}", dir.display());
    println!("buckets: {:?}", man.buckets());
    println!("{} artifacts, fit_steps={}", man.artifacts.len(), man.fit_steps);
    #[cfg(feature = "xla")]
    {
        let mut engine = lkgp::runtime::XlaEngine::load(&dir)?;
        // compile one executable as a health check
        let data = lkgp::lcbench::toy_dataset(8, 16, 3, 1);
        let theta = lkgp::gp::Theta::default_packed(3);
        let (value, _grad, iters) = engine.mll_grad(&theta, &data, 0)?;
        println!("health check: mll={value:.3} (cg iters {iters}) OK");
    }
    #[cfg(not(feature = "xla"))]
    println!("(xla feature disabled: manifest checked, executables not compiled)");
    Ok(())
}

fn cmd_smoke(args: &Args) -> lkgp::Result<()> {
    use lkgp::gp::{Answer, Query};
    let seed = args.get_u64("seed", 0);
    let prefer_xla = args.get("engine").unwrap_or("xla") == "xla";
    let mut engine: Box<dyn lkgp::runtime::Engine> =
        if args.get("trainer") == Some("lbfgs") {
            // paper-faithful: L-BFGS on the MAP objective (rust engine)
            Box::new(lkgp::runtime::RustEngine::with_lbfgs())
        } else {
            lkgp::runtime::open_engine(prefer_xla)
        };
    let data = std::sync::Arc::new(lkgp::lcbench::toy_dataset(16, 16, 3, seed));
    let theta0 = lkgp::gp::Theta::default_packed(3);
    let theta = engine.fit(&theta0, &data, seed)?;
    let xq = lkgp::linalg::Matrix::from_vec(2, 3, vec![0.3, 0.5, 0.7, 0.6, 0.2, 0.9]);
    // one typed-query batch: mean/variance and quantile band from a
    // single underlying solve (see docs/api.md)
    let outcome = engine.answer_batch(
        &theta,
        &data,
        &[
            Query::MeanAtFinal { xq: xq.clone() },
            Query::Quantiles { xq: xq.clone(), ps: vec![0.1, 0.9] },
        ],
        None,
        None,
        None,
    )?;
    println!("engine={} theta={theta:.3?}", engine.name());
    let (finals, bands) = match (&outcome.answers[0], &outcome.answers[1]) {
        (Answer::Final(f), Answer::Quantiles(q)) => (f, q),
        _ => unreachable!("smoke queries answer Final + Quantiles"),
    };
    for (i, (mu, var)) in finals.iter().enumerate() {
        println!(
            "query {i}: final = {mu:.4} +- {:.4}  (p10={:.4} p90={:.4})",
            var.sqrt(),
            bands[(i, 0)],
            bands[(i, 1)],
        );
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> lkgp::Result<()> {
    lkgp::coordinator::serve_simulated(args)
}
