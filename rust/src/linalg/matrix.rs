//! Dense row-major f64 matrix with the operations the GP stack needs.
//!
//! Deliberately minimal: no generic scalar, no views, no broadcasting — the
//! engines work with explicit shapes and the hot paths (panel-parallel
//! matmul, fused masked products) live here so they can be profiled and
//! tuned in one place (EXPERIMENTS.md §Perf).

use std::ops::{Index, IndexMut};

use crate::metrics::alloc::note_alloc;

/// Row-major dense matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Matrix {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        note_alloc(rows * cols * 8);
        Matrix {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    /// Identity.
    pub fn eye(n: usize) -> Self {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// From a row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(rows * cols, data.len(), "shape/buffer mismatch");
        note_alloc(rows * cols * 8);
        Matrix { rows, cols, data }
    }

    /// Build from a function of (i, j).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut m = Matrix::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m[(i, j)] = f(i, j);
            }
        }
        m
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn data(&self) -> &[f64] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f64> {
        self.data
    }

    /// Borrow row `i` as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out[(j, i)] = self[(i, j)];
            }
        }
        out
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Max |a_ij - b_ij|.
    pub fn max_abs_diff(&self, other: &Matrix) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// `self * other` — panel-parallel blocked matmul (the rust engine's
    /// hot path; see `matmul_into` for the kernel).
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(self.rows, other.cols);
        self.matmul_into(other, &mut out);
        out
    }

    /// `out = self * other`, reusing `out`'s buffer.
    ///
    /// i-k-j loop order keeps the inner loop contiguous in both `other` and
    /// `out` (auto-vectorizes); row panels (n-axis blocks) are distributed
    /// over the persistent [`crate::util::team::WorkerTeam`] when the
    /// product is big enough to amortize the handoff. The panel split is
    /// keyed by the logical thread count, and each panel's arithmetic is
    /// independent of where it runs, so results are bit-identical for
    /// every thread count (including the sequential path).
    pub fn matmul_into(&self, other: &Matrix, out: &mut Matrix) {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        assert_eq!((out.rows, out.cols), (self.rows, other.cols));
        let (n, k, m) = (self.rows, self.cols, other.cols);
        let flops = 2.0 * n as f64 * k as f64 * m as f64;
        let threads = crate::util::num_threads();
        if nested_parallelism_disabled() || threads <= 1 || flops < 4e6 || n < 2 * threads {
            matmul_panel(&self.data, &other.data, &mut out.data, 0, n, k, m);
            return;
        }
        // One row panel per logical thread; the team maps panels onto
        // however many lanes are actually free.
        let chunk = n.div_ceil(threads);
        let parts = n.div_ceil(chunk);
        let a = &self.data;
        let b = &other.data;
        let base = SendMutPtr(out.data.as_mut_ptr());
        crate::util::team::WorkerTeam::global().run(parts, &|p| {
            let row0 = p * chunk;
            let rows = chunk.min(n - row0);
            // SAFETY: panels [row0, row0 + rows) are disjoint across part
            // indices and the team's barrier keeps `out` borrowed for the
            // duration; each part writes only its own panel.
            let panel =
                unsafe { std::slice::from_raw_parts_mut(base.0.add(row0 * m), rows * m) };
            matmul_panel_slice(a, b, panel, row0, rows, k, m);
        });
    }

    /// Matrix-vector product.
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, v.len());
        let mut out = vec![0.0; self.rows];
        for i in 0..self.rows {
            out[i] = dot(self.row(i), v);
        }
        out
    }

    /// `self + scale * eye`.
    pub fn add_diag(&mut self, scale: f64) {
        let n = self.rows.min(self.cols);
        for i in 0..n {
            self[(i, i)] += scale;
        }
    }

    /// Elementwise addition in place.
    pub fn add_assign(&mut self, other: &Matrix) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    /// Elementwise scale in place.
    pub fn scale(&mut self, s: f64) {
        for a in self.data.iter_mut() {
            *a *= s;
        }
    }
}

impl Index<(usize, usize)> for Matrix {
    type Output = f64;

    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

/// Shared raw base pointer for lending disjoint output panels to worker
/// team parts (each part computes its own slice bounds from the part
/// index; see the SAFETY notes at the use sites).
pub(crate) struct SendMutPtr(pub(crate) *mut f64);
// SAFETY: only the pointer value is shared; every use site derives
// disjoint per-part panels from it (each carries its own SAFETY note)
// and the pointee outlives the team run that borrows it.
unsafe impl Send for SendMutPtr {}
// SAFETY: same argument — concurrent access never touches overlapping
// elements, so &SendMutPtr is safe to share across the team.
unsafe impl Sync for SendMutPtr {}

thread_local! {
    static DISABLE_PAR: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

/// True when an outer parallel region disabled nested matmul threading.
pub fn nested_parallelism_disabled() -> bool {
    DISABLE_PAR.with(|f| f.get())
}

/// Run `f` with panel-parallel matmul disabled on this thread (used by
/// outer parallel regions — batch-parallel CG, column-parallel inverse —
/// to avoid thread oversubscription).
pub fn without_nested_parallelism<T>(f: impl FnOnce() -> T) -> T {
    DISABLE_PAR.with(|flag| {
        let prev = flag.get();
        flag.set(true);
        let out = f();
        flag.set(prev);
        out
    })
}

/// Dot product with 4-way unrolling (reliably vectorized by LLVM).
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f64; 4];
    let chunks = a.len() / 4;
    for c in 0..chunks {
        let i = c * 4;
        acc[0] += a[i] * b[i];
        acc[1] += a[i + 1] * b[i + 1];
        acc[2] += a[i + 2] * b[i + 2];
        acc[3] += a[i + 3] * b[i + 3];
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for i in chunks * 4..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// axpy: y += a * x.
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// Fused axpby: y = a * x + b * y (one pass, auto-vectorizes).
///
/// With a = 1.0 the multiply is exact (IEEE), so `axpby(1.0, x, b, y)` is
/// bitwise identical to the scalar loop `y[i] = x[i] + b * y[i]` — the CG
/// search-direction update relies on this for bit-exact solver parity.
#[inline]
pub fn axpby(a: f64, x: &[f64], b: f64, y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi = a * xi + b * *yi;
    }
}

/// Row-panel matmul kernel: rows [row0, row0+rows) of out = A[those rows] * B.
fn matmul_panel(a: &[f64], b: &[f64], out: &mut [f64], row0: usize, rows_end: usize, k: usize, m: usize) {
    for i in row0..rows_end {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * m..(i + 1) * m];
        orow.fill(0.0);
        for (kk, &aik) in arow.iter().enumerate() {
            // lint: allow(float_eq) — exact-zero sparsity skip: only a
            // bitwise zero contributes nothing to the row product, and
            // the mask semantics make 0.0 the structural-hole sentinel.
            if aik != 0.0 {
                axpy(aik, &b[kk * m..(kk + 1) * m], orow);
            }
        }
    }
}

/// Same kernel but writing into a detached output slice (thread panels).
fn matmul_panel_slice(a: &[f64], b: &[f64], out: &mut [f64], row0: usize, rows: usize, k: usize, m: usize) {
    for r in 0..rows {
        let i = row0 + r;
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[r * m..(r + 1) * m];
        orow.fill(0.0);
        for (kk, &aik) in arow.iter().enumerate() {
            // lint: allow(float_eq) — exact-zero sparsity skip: only a
            // bitwise zero contributes nothing to the row product, and
            // the mask semantics make 0.0 the structural-hole sentinel.
            if aik != 0.0 {
                axpy(aik, &b[kk * m..(kk + 1) * m], orow);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Mixed precision: f32 storage, f64 accumulation

/// Row-major f32 snapshot of a [`Matrix`] — the storage half of the
/// mixed-precision fast path (arXiv 2312.15305 direction): kernel factors
/// are rounded once to f32 (halving memory traffic on the MVM-bound
/// solves), while every product and sum still accumulates in f64. The
/// iterative-refinement driver (`linalg::pcg::refined_solve`) recovers
/// f64-grade residuals on top.
#[derive(Clone, Debug, PartialEq)]
pub struct MatrixF32 {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl MatrixF32 {
    /// Round an f64 matrix to f32 storage.
    pub fn from_f64(m: &Matrix) -> Self {
        note_alloc(m.rows() * m.cols() * 4);
        MatrixF32 {
            rows: m.rows(),
            cols: m.cols(),
            data: m.data().iter().map(|&v| v as f32).collect(),
        }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Widen back to f64 (tests / diagnostics).
    pub fn to_f64(&self) -> Matrix {
        Matrix::from_vec(self.rows, self.cols, self.data.iter().map(|&v| v as f64).collect())
    }
}

/// `out = a · b32` — f64 left operand, f32-storage right operand, f64
/// accumulation. Same i-k-j panel kernel as `matmul_into`, with the B row
/// widened element-wise inside the axpy. Sequential by design: the
/// mixed-precision operator parallelizes one level up (across batch RHS).
pub fn matmul_mixed_ab32(a: &Matrix, b32: &MatrixF32, out: &mut Matrix) {
    assert_eq!(a.cols(), b32.rows(), "matmul shape mismatch");
    assert_eq!((out.rows(), out.cols()), (a.rows(), b32.cols()));
    let (n, k, m) = (a.rows(), a.cols(), b32.cols());
    let (ad, bd) = (a.data(), b32.data());
    let od = out.data_mut();
    for i in 0..n {
        let arow = &ad[i * k..(i + 1) * k];
        let orow = &mut od[i * m..(i + 1) * m];
        orow.fill(0.0);
        for (kk, &aik) in arow.iter().enumerate() {
            // lint: allow(float_eq) — exact-zero sparsity skip: only a
            // bitwise zero contributes nothing to the row product, and
            // the mask semantics make 0.0 the structural-hole sentinel.
            if aik != 0.0 {
                let brow = &bd[kk * m..(kk + 1) * m];
                for (o, &b) in orow.iter_mut().zip(brow) {
                    *o += aik * b as f64;
                }
            }
        }
    }
}

/// `out = a32 · b` — f32-storage left operand, f64 right operand, f64
/// accumulation (the widened `a_ik` multiplies full-precision B rows).
pub fn matmul_mixed_a32b(a32: &MatrixF32, b: &Matrix, out: &mut Matrix) {
    assert_eq!(a32.cols(), b.rows(), "matmul shape mismatch");
    assert_eq!((out.rows(), out.cols()), (a32.rows(), b.cols()));
    let (n, k, m) = (a32.rows(), a32.cols(), b.cols());
    let (ad, bd) = (a32.data(), b.data());
    let od = out.data_mut();
    for i in 0..n {
        let arow = &ad[i * k..(i + 1) * k];
        let orow = &mut od[i * m..(i + 1) * m];
        orow.fill(0.0);
        for (kk, &aik) in arow.iter().enumerate() {
            // lint: allow(float_eq) — exact-zero sparsity skip: only a
            // bitwise zero contributes nothing to the row product, and
            // the mask semantics make 0.0 the structural-hole sentinel.
            if aik != 0.0 {
                axpy(aik as f64, &bd[kk * m..(kk + 1) * m], orow);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_and_from_fn() {
        let m = Matrix::from_fn(3, 4, |i, j| (i * 10 + j) as f64);
        assert_eq!(m[(2, 3)], 23.0);
        assert_eq!(m.row(1), &[10.0, 11.0, 12.0, 13.0]);
    }

    #[test]
    fn matmul_small_exact() {
        let a = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let b = Matrix::from_vec(3, 2, vec![7., 8., 9., 10., 11., 12.]);
        let c = a.matmul(&b);
        assert_eq!(c.data(), &[58., 64., 139., 154.]);
    }

    #[test]
    fn matmul_matches_naive_large() {
        let mut rng = crate::rng::Pcg64::new(0);
        let (n, k, m) = (67, 43, 55);
        let a = Matrix::from_vec(n, k, rng.normal_vec(n * k));
        let b = Matrix::from_vec(k, m, rng.normal_vec(k * m));
        let c = a.matmul(&b);
        let mut naive = Matrix::zeros(n, m);
        for i in 0..n {
            for j in 0..m {
                let mut s = 0.0;
                for kk in 0..k {
                    s += a[(i, kk)] * b[(kk, j)];
                }
                naive[(i, j)] = s;
            }
        }
        assert!(c.max_abs_diff(&naive) < 1e-10);
    }

    #[test]
    fn matmul_parallel_path_matches() {
        // Big enough to trigger the threaded path.
        let mut rng = crate::rng::Pcg64::new(1);
        let n = 256;
        let a = Matrix::from_vec(n, n, rng.normal_vec(n * n));
        let b = Matrix::from_vec(n, n, rng.normal_vec(n * n));
        let c = a.matmul(&b);
        // Spot-check a few entries against dot products.
        let bt = b.transpose();
        for &(i, j) in &[(0, 0), (17, 200), (255, 255), (100, 3)] {
            let want = dot(a.row(i), bt.row(j));
            assert!((c[(i, j)] - want).abs() < 1e-9);
        }
    }

    #[test]
    fn matvec_matches_matmul() {
        let mut rng = crate::rng::Pcg64::new(2);
        let a = Matrix::from_vec(9, 7, rng.normal_vec(63));
        let v = rng.normal_vec(7);
        let got = a.matvec(&v);
        let vm = Matrix::from_vec(7, 1, v);
        let want = a.matmul(&vm);
        for i in 0..9 {
            assert!((got[i] - want[(i, 0)]).abs() < 1e-12);
        }
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = crate::rng::Pcg64::new(3);
        let a = Matrix::from_vec(5, 8, rng.normal_vec(40));
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn axpby_matches_scalar_loop_bitwise() {
        let mut rng = crate::rng::Pcg64::new(4);
        let x = rng.normal_vec(37);
        let y0 = rng.normal_vec(37);
        let beta = 0.73;
        let mut want = y0.clone();
        for i in 0..37 {
            want[i] = x[i] + beta * want[i];
        }
        let mut got = y0.clone();
        axpby(1.0, &x, beta, &mut got);
        assert_eq!(got, want);
        // general coefficients
        let mut g2 = y0.clone();
        axpby(-2.5, &x, 0.5, &mut g2);
        for i in 0..37 {
            assert!((g2[i] - (-2.5 * x[i] + 0.5 * y0[i])).abs() < 1e-15);
        }
    }

    #[test]
    fn mixed_kernels_match_f64_within_f32_rounding() {
        let mut rng = crate::rng::Pcg64::new(11);
        let (n, k, m) = (23, 17, 19);
        let a = Matrix::from_vec(n, k, rng.normal_vec(n * k));
        let b = Matrix::from_vec(k, m, rng.normal_vec(k * m));
        let exact = a.matmul(&b);
        let scale = a.fro_norm() * b.fro_norm();

        let b32 = MatrixF32::from_f64(&b);
        let mut got = Matrix::zeros(n, m);
        matmul_mixed_ab32(&a, &b32, &mut got);
        assert!(got.max_abs_diff(&exact) < 1e-5 * scale, "ab32");
        // Bit-exact against the widened-storage oracle: only the storage
        // rounding differs from f64, never the accumulation.
        let oracle = a.matmul(&b32.to_f64());
        assert_eq!(got.data(), oracle.data(), "ab32 accumulation drifted");

        let a32 = MatrixF32::from_f64(&a);
        let mut got2 = Matrix::zeros(n, m);
        matmul_mixed_a32b(&a32, &b, &mut got2);
        assert!(got2.max_abs_diff(&exact) < 1e-5 * scale, "a32b");
        let oracle2 = a32.to_f64().matmul(&b);
        assert_eq!(got2.data(), oracle2.data(), "a32b accumulation drifted");
    }

    #[test]
    fn matrix_f32_roundtrip_shapes() {
        let m = Matrix::from_fn(4, 6, |i, j| (i * 6 + j) as f64 * 0.5);
        let m32 = MatrixF32::from_f64(&m);
        assert_eq!((m32.rows(), m32.cols()), (4, 6));
        assert_eq!(m32.to_f64(), m, "small integers/halves are f32-exact");
    }

    #[test]
    fn add_diag_and_scale() {
        let mut m = Matrix::zeros(3, 3);
        m.add_diag(2.0);
        m.scale(1.5);
        assert_eq!(m[(1, 1)], 3.0);
        assert_eq!(m[(0, 1)], 0.0);
    }
}
