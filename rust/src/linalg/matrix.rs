//! Dense row-major f64 matrix with the operations the GP stack needs.
//!
//! Deliberately minimal: no generic scalar, no views, no broadcasting — the
//! engines work with explicit shapes and the hot paths (panel-parallel
//! matmul, fused masked products) live here so they can be profiled and
//! tuned in one place (EXPERIMENTS.md §Perf).

use std::ops::{Index, IndexMut};

use crate::metrics::alloc::note_alloc;

/// Row-major dense matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Matrix {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        note_alloc(rows * cols * 8);
        Matrix {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    /// Identity.
    pub fn eye(n: usize) -> Self {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// From a row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(rows * cols, data.len(), "shape/buffer mismatch");
        note_alloc(rows * cols * 8);
        Matrix { rows, cols, data }
    }

    /// Build from a function of (i, j).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut m = Matrix::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m[(i, j)] = f(i, j);
            }
        }
        m
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn data(&self) -> &[f64] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f64> {
        self.data
    }

    /// Borrow row `i` as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out[(j, i)] = self[(i, j)];
            }
        }
        out
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Max |a_ij - b_ij|.
    pub fn max_abs_diff(&self, other: &Matrix) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// `self * other` — panel-parallel blocked matmul (the rust engine's
    /// hot path; see `matmul_into` for the kernel).
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(self.rows, other.cols);
        self.matmul_into(other, &mut out);
        out
    }

    /// `out = self * other`, reusing `out`'s buffer.
    ///
    /// i-k-j loop order keeps the inner loop contiguous in both `other` and
    /// `out` (auto-vectorizes); row panels are distributed over threads when
    /// the product is big enough to amortize spawn cost.
    pub fn matmul_into(&self, other: &Matrix, out: &mut Matrix) {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        assert_eq!((out.rows, out.cols), (self.rows, other.cols));
        let (n, k, m) = (self.rows, self.cols, other.cols);
        let flops = 2.0 * n as f64 * k as f64 * m as f64;
        let threads = crate::util::num_threads();
        if nested_parallelism_disabled() || threads <= 1 || flops < 4e6 || n < 2 * threads {
            matmul_panel(&self.data, &other.data, &mut out.data, 0, n, k, m);
            return;
        }
        // Split rows into one panel per thread.
        let chunk = n.div_ceil(threads);
        let a = &self.data;
        let b = &other.data;
        let out_chunks: Vec<(usize, &mut [f64])> = out
            .data
            .chunks_mut(chunk * m)
            .enumerate()
            .map(|(ci, c)| (ci * chunk, c))
            .collect();
        std::thread::scope(|scope| {
            for (row0, chunk_out) in out_chunks {
                let rows = chunk_out.len() / m;
                scope.spawn(move || {
                    matmul_panel_slice(a, b, chunk_out, row0, rows, k, m);
                });
            }
        });
    }

    /// Matrix-vector product.
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, v.len());
        let mut out = vec![0.0; self.rows];
        for i in 0..self.rows {
            out[i] = dot(self.row(i), v);
        }
        out
    }

    /// `self + scale * eye`.
    pub fn add_diag(&mut self, scale: f64) {
        let n = self.rows.min(self.cols);
        for i in 0..n {
            self[(i, i)] += scale;
        }
    }

    /// Elementwise addition in place.
    pub fn add_assign(&mut self, other: &Matrix) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    /// Elementwise scale in place.
    pub fn scale(&mut self, s: f64) {
        for a in self.data.iter_mut() {
            *a *= s;
        }
    }
}

impl Index<(usize, usize)> for Matrix {
    type Output = f64;

    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

thread_local! {
    static DISABLE_PAR: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

/// True when an outer parallel region disabled nested matmul threading.
pub fn nested_parallelism_disabled() -> bool {
    DISABLE_PAR.with(|f| f.get())
}

/// Run `f` with panel-parallel matmul disabled on this thread (used by
/// outer parallel regions — batch-parallel CG, column-parallel inverse —
/// to avoid thread oversubscription).
pub fn without_nested_parallelism<T>(f: impl FnOnce() -> T) -> T {
    DISABLE_PAR.with(|flag| {
        let prev = flag.get();
        flag.set(true);
        let out = f();
        flag.set(prev);
        out
    })
}

/// Dot product with 4-way unrolling (reliably vectorized by LLVM).
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f64; 4];
    let chunks = a.len() / 4;
    for c in 0..chunks {
        let i = c * 4;
        acc[0] += a[i] * b[i];
        acc[1] += a[i + 1] * b[i + 1];
        acc[2] += a[i + 2] * b[i + 2];
        acc[3] += a[i + 3] * b[i + 3];
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for i in chunks * 4..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// axpy: y += a * x.
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// Fused axpby: y = a * x + b * y (one pass, auto-vectorizes).
///
/// With a = 1.0 the multiply is exact (IEEE), so `axpby(1.0, x, b, y)` is
/// bitwise identical to the scalar loop `y[i] = x[i] + b * y[i]` — the CG
/// search-direction update relies on this for bit-exact solver parity.
#[inline]
pub fn axpby(a: f64, x: &[f64], b: f64, y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi = a * xi + b * *yi;
    }
}

/// Row-panel matmul kernel: rows [row0, row0+rows) of out = A[those rows] * B.
fn matmul_panel(a: &[f64], b: &[f64], out: &mut [f64], row0: usize, rows_end: usize, k: usize, m: usize) {
    for i in row0..rows_end {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * m..(i + 1) * m];
        orow.fill(0.0);
        for (kk, &aik) in arow.iter().enumerate() {
            if aik != 0.0 {
                axpy(aik, &b[kk * m..(kk + 1) * m], orow);
            }
        }
    }
}

/// Same kernel but writing into a detached output slice (thread panels).
fn matmul_panel_slice(a: &[f64], b: &[f64], out: &mut [f64], row0: usize, rows: usize, k: usize, m: usize) {
    for r in 0..rows {
        let i = row0 + r;
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[r * m..(r + 1) * m];
        orow.fill(0.0);
        for (kk, &aik) in arow.iter().enumerate() {
            if aik != 0.0 {
                axpy(aik, &b[kk * m..(kk + 1) * m], orow);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_and_from_fn() {
        let m = Matrix::from_fn(3, 4, |i, j| (i * 10 + j) as f64);
        assert_eq!(m[(2, 3)], 23.0);
        assert_eq!(m.row(1), &[10.0, 11.0, 12.0, 13.0]);
    }

    #[test]
    fn matmul_small_exact() {
        let a = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let b = Matrix::from_vec(3, 2, vec![7., 8., 9., 10., 11., 12.]);
        let c = a.matmul(&b);
        assert_eq!(c.data(), &[58., 64., 139., 154.]);
    }

    #[test]
    fn matmul_matches_naive_large() {
        let mut rng = crate::rng::Pcg64::new(0);
        let (n, k, m) = (67, 43, 55);
        let a = Matrix::from_vec(n, k, rng.normal_vec(n * k));
        let b = Matrix::from_vec(k, m, rng.normal_vec(k * m));
        let c = a.matmul(&b);
        let mut naive = Matrix::zeros(n, m);
        for i in 0..n {
            for j in 0..m {
                let mut s = 0.0;
                for kk in 0..k {
                    s += a[(i, kk)] * b[(kk, j)];
                }
                naive[(i, j)] = s;
            }
        }
        assert!(c.max_abs_diff(&naive) < 1e-10);
    }

    #[test]
    fn matmul_parallel_path_matches() {
        // Big enough to trigger the threaded path.
        let mut rng = crate::rng::Pcg64::new(1);
        let n = 256;
        let a = Matrix::from_vec(n, n, rng.normal_vec(n * n));
        let b = Matrix::from_vec(n, n, rng.normal_vec(n * n));
        let c = a.matmul(&b);
        // Spot-check a few entries against dot products.
        let bt = b.transpose();
        for &(i, j) in &[(0, 0), (17, 200), (255, 255), (100, 3)] {
            let want = dot(a.row(i), bt.row(j));
            assert!((c[(i, j)] - want).abs() < 1e-9);
        }
    }

    #[test]
    fn matvec_matches_matmul() {
        let mut rng = crate::rng::Pcg64::new(2);
        let a = Matrix::from_vec(9, 7, rng.normal_vec(63));
        let v = rng.normal_vec(7);
        let got = a.matvec(&v);
        let vm = Matrix::from_vec(7, 1, v);
        let want = a.matmul(&vm);
        for i in 0..9 {
            assert!((got[i] - want[(i, 0)]).abs() < 1e-12);
        }
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = crate::rng::Pcg64::new(3);
        let a = Matrix::from_vec(5, 8, rng.normal_vec(40));
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn axpby_matches_scalar_loop_bitwise() {
        let mut rng = crate::rng::Pcg64::new(4);
        let x = rng.normal_vec(37);
        let y0 = rng.normal_vec(37);
        let beta = 0.73;
        let mut want = y0.clone();
        for i in 0..37 {
            want[i] = x[i] + beta * want[i];
        }
        let mut got = y0.clone();
        axpby(1.0, &x, beta, &mut got);
        assert_eq!(got, want);
        // general coefficients
        let mut g2 = y0.clone();
        axpby(-2.5, &x, 0.5, &mut g2);
        for i in 0..37 {
            assert!((g2[i] - (-2.5 * x[i] + 0.5 * y0[i])).abs() < 1e-15);
        }
    }

    #[test]
    fn add_diag_and_scale() {
        let mut m = Matrix::zeros(3, 3);
        m.add_diag(2.0);
        m.scale(1.5);
        assert_eq!(m[(1, 1)], 3.0);
        assert_eq!(m[(0, 1)], 0.0);
    }
}
