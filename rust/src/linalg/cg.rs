//! Batched conjugate gradients over abstract linear operators.
//!
//! The LKGP engine never materializes the joint covariance: training and
//! prediction reduce to solves against the masked latent-Kronecker operator
//! (paper §2, "Efficient Inference with Iterative Methods"). This module is
//! the operator-agnostic solver; the operator lives in `gp::operator`.

/// A symmetric positive-definite linear operator on batched vectors.
///
/// `apply` maps a batch of `len()`-dim vectors (row-major, one per row of
/// the flattened buffer) to their images. Implementations are expected to
/// be thread-safe (&self).
pub trait LinOp: Sync {
    /// Dimension of the space.
    fn len(&self) -> usize;

    /// Whether the space is empty (clippy convention).
    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// out[b] = A x[b] for each batch row b.
    fn apply_batch(&self, x: &[f64], out: &mut [f64], batch: usize);
}

/// Convergence report for a CG solve.
#[derive(Clone, Debug)]
pub struct CgStats {
    /// Iterations used (max over the batch).
    pub iters: usize,
    /// Relative residual per batch element at exit.
    pub rel_residual: Vec<f64>,
    /// Whether every system met the tolerance.
    pub converged: bool,
    /// Total operator applications (= iters; one fused batch MVM each).
    pub mvms: usize,
}

/// Solve A X = B for a batch of right-hand sides with plain CG.
///
/// `b` is row-major (batch, len). Returns the solutions and stats. Systems
/// that converge early are frozen (their alpha/beta forced to 0) so the
/// remaining systems keep full-precision updates — this mirrors GPyTorch's
/// batched CG semantics that the paper relies on (§B: tol 0.01).
pub fn cg_batch(op: &dyn LinOp, b: &[f64], tol: f64, max_iters: usize) -> (Vec<f64>, CgStats) {
    let n = op.len();
    let batch = if n == 0 { 0 } else { b.len() / n };
    debug_assert_eq!(b.len(), batch * n);

    let mut x = vec![0.0; b.len()];
    let mut r = b.to_vec();
    let mut p = b.to_vec();
    let mut ap = vec![0.0; b.len()];

    let bnorm: Vec<f64> = (0..batch)
        .map(|bi| norm(&b[bi * n..(bi + 1) * n]).max(1e-300))
        .collect();
    let mut rs: Vec<f64> = (0..batch)
        .map(|bi| {
            let rb = &r[bi * n..(bi + 1) * n];
            crate::linalg::matrix::dot(rb, rb)
        })
        .collect();

    let mut iters = 0;
    for _ in 0..max_iters {
        let active: Vec<bool> = (0..batch)
            .map(|bi| rs[bi].sqrt() > tol * bnorm[bi])
            .collect();
        if !active.iter().any(|&a| a) {
            break;
        }
        iters += 1;
        op.apply_batch(&p, &mut ap, batch);
        for bi in 0..batch {
            if !active[bi] {
                continue;
            }
            let (pb, apb) = (&p[bi * n..(bi + 1) * n], &ap[bi * n..(bi + 1) * n]);
            let denom = crate::linalg::matrix::dot(pb, apb);
            if denom <= 0.0 || !denom.is_finite() {
                // Operator not PD along p (should not happen); freeze.
                rs[bi] = 0.0;
                continue;
            }
            let alpha = rs[bi] / denom;
            let (xb, rb) = (bi * n, (bi + 1) * n);
            {
                let pslice = &p[xb..rb];
                let xs = &mut x[xb..rb];
                crate::linalg::matrix::axpy(alpha, pslice, xs);
            }
            {
                let apslice = &ap[xb..rb];
                let rsl = &mut r[xb..rb];
                crate::linalg::matrix::axpy(-alpha, apslice, rsl);
            }
            let rnew = {
                let rsl = &r[xb..rb];
                crate::linalg::matrix::dot(rsl, rsl)
            };
            let beta = rnew / rs[bi];
            rs[bi] = rnew;
            let (rsl, psl) = (&r[xb..rb], &mut p[xb..rb]);
            for i in 0..n {
                psl[i] = rsl[i] + beta * psl[i];
            }
        }
    }

    let rel: Vec<f64> = (0..batch).map(|bi| rs[bi].sqrt() / bnorm[bi]).collect();
    let converged = rel.iter().all(|&r| r <= tol * 1.0001);
    (
        x,
        CgStats {
            iters,
            rel_residual: rel,
            converged,
            mvms: iters,
        },
    )
}

fn norm(v: &[f64]) -> f64 {
    crate::linalg::matrix::dot(v, v).sqrt()
}

/// Dense matrix as a LinOp (tests + the naive engine's solver reuse).
pub struct DenseOp<'a>(pub &'a crate::linalg::Matrix);

impl LinOp for DenseOp<'_> {
    fn len(&self) -> usize {
        self.0.rows()
    }

    fn apply_batch(&self, x: &[f64], out: &mut [f64], batch: usize) {
        let n = self.len();
        for bi in 0..batch {
            let xi = &x[bi * n..(bi + 1) * n];
            let oi = self.0.matvec(xi);
            out[bi * n..(bi + 1) * n].copy_from_slice(&oi);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::rng::Pcg64;

    fn random_spd(n: usize, seed: u64) -> Matrix {
        let mut rng = Pcg64::new(seed);
        let a = Matrix::from_vec(n, n, rng.normal_vec(n * n));
        let mut spd = a.matmul(&a.transpose());
        spd.add_diag(n as f64 * 0.5);
        spd
    }

    #[test]
    fn solves_dense_system() {
        let n = 40;
        let a = random_spd(n, 1);
        let mut rng = Pcg64::new(2);
        let b = rng.normal_vec(n);
        let (x, stats) = cg_batch(&DenseOp(&a), &b, 1e-10, 500);
        assert!(stats.converged, "rel={:?}", stats.rel_residual);
        let back = a.matvec(&x);
        for i in 0..n {
            assert!((back[i] - b[i]).abs() < 1e-7);
        }
    }

    #[test]
    fn batched_rhs_all_converge() {
        let n = 25;
        let batch = 6;
        let a = random_spd(n, 3);
        let mut rng = Pcg64::new(4);
        let b = rng.normal_vec(n * batch);
        let (x, stats) = cg_batch(&DenseOp(&a), &b, 1e-9, 400);
        assert!(stats.converged);
        for bi in 0..batch {
            let back = a.matvec(&x[bi * n..(bi + 1) * n]);
            for i in 0..n {
                assert!((back[i] - b[bi * n + i]).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn zero_rhs_is_identity_map() {
        let a = random_spd(10, 5);
        let b = vec![0.0; 10];
        let (x, stats) = cg_batch(&DenseOp(&a), &b, 1e-8, 100);
        assert_eq!(stats.iters, 0);
        assert!(x.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn loose_tolerance_converges_fast() {
        let n = 60;
        let a = random_spd(n, 6);
        let mut rng = Pcg64::new(7);
        let b = rng.normal_vec(n);
        let (_, tight) = cg_batch(&DenseOp(&a), &b, 1e-12, 1000);
        let (_, loose) = cg_batch(&DenseOp(&a), &b, 1e-2, 1000);
        assert!(loose.iters < tight.iters);
        assert!(loose.converged);
    }

    #[test]
    fn mixed_convergence_freezes_done_systems() {
        // One trivial RHS (eigvec direction) + one hard RHS.
        let n = 30;
        let a = random_spd(n, 8);
        let mut b = vec![0.0; 2 * n];
        b[0] = 1.0; // converges in a few iters along e0? still fine
        let mut rng = Pcg64::new(9);
        for i in 0..n {
            b[n + i] = rng.normal();
        }
        let (x, stats) = cg_batch(&DenseOp(&a), &b, 1e-9, 500);
        assert!(stats.converged);
        for bi in 0..2 {
            let back = a.matvec(&x[bi * n..(bi + 1) * n]);
            for i in 0..n {
                assert!((back[i] - b[bi * n + i]).abs() < 1e-6);
            }
        }
    }
}
