//! Batched conjugate gradients over abstract linear operators.
//!
//! The LKGP engine never materializes the joint covariance: training and
//! prediction reduce to solves against the masked latent-Kronecker operator
//! (paper §2, "Efficient Inference with Iterative Methods"). This module is
//! the operator-agnostic solver; the operator lives in `gp::operator`.

/// A symmetric positive-definite linear operator on batched vectors.
///
/// `apply` maps a batch of `len()`-dim vectors (row-major, one per row of
/// the flattened buffer) to their images. Implementations are expected to
/// be thread-safe (&self).
pub trait LinOp: Sync {
    /// Dimension of the space.
    fn len(&self) -> usize;

    /// Whether the space is empty (clippy convention).
    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// out[b] = A x[b] for each batch row b.
    fn apply_batch(&self, x: &[f64], out: &mut [f64], batch: usize);
}

/// Typed health of a solve, threaded from the CG core up through
/// `gp::lkgp`/`gp::session` so callers never mistake a broken solve for a
/// converged one (docs/robustness.md).
///
/// Ordering matters for severity comparisons: `Converged` is healthy,
/// everything after it escalates.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum SolveHealth {
    /// Every RHS met the tolerance and all residuals are finite.
    Converged,
    /// Iteration budget exhausted with finite residuals (the classic
    /// ill-conditioned stall; a bigger budget or a preconditioner helps).
    MaxIters,
    /// The Krylov process broke down: a search direction hit a
    /// non-positive or non-finite curvature (`pᵀAp ≤ 0`). The RHS was
    /// frozen at its last iterate — historically this masqueraded as
    /// convergence because the frozen residual norm was zeroed.
    Breakdown,
    /// A non-finite value (NaN/Inf) reached a residual or iterate.
    NonFinite,
}

impl SolveHealth {
    /// Whether the solve can be trusted as-is.
    pub fn is_healthy(self) -> bool {
        self == SolveHealth::Converged
    }

    /// Stable lower-case tag for logs, counters, and `LkgpError::Solver`.
    pub fn tag(self) -> &'static str {
        match self {
            SolveHealth::Converged => "converged",
            SolveHealth::MaxIters => "max_iters",
            SolveHealth::Breakdown => "breakdown",
            SolveHealth::NonFinite => "non_finite",
        }
    }
}

/// Convergence report for a CG solve.
#[derive(Clone, Debug)]
pub struct CgStats {
    /// Iterations used (max over the batch).
    pub iters: usize,
    /// Iterations each batch element was active for (per-RHS work; warm
    /// starts show up here as elements converging in 0-2 iterations).
    pub iters_per_rhs: Vec<usize>,
    /// Relative residual per batch element at exit.
    pub rel_residual: Vec<f64>,
    /// Whether every system met the tolerance.
    pub converged: bool,
    /// Total batched operator applications (iters, plus one residual apply
    /// when a warm start was used).
    pub mvms: usize,
    /// Total per-RHS operator rows applied. Converged systems are
    /// compacted out of the batch before each apply, so this is the true
    /// MVM work: `sum(iters_per_rhs)` plus `batch` rows for the warm
    /// residual. Without compaction it would be `batch * mvms`.
    pub mvm_rows: usize,
    /// RHS count frozen by a Krylov breakdown (`pᵀAp ≤ 0` or non-finite
    /// curvature). A frozen RHS carries its last iterate, NOT a converged
    /// solution; `converged` is forced false whenever this is non-zero.
    pub breakdowns: usize,
    /// Whether any residual or iterate went non-finite (NaN/Inf).
    pub non_finite: bool,
    /// Escalation-ladder rungs climbed beyond the configured solve
    /// (`gp::lkgp::solve_healthy`; 0 on the healthy fast path — the core
    /// solvers always report 0 here).
    pub escalations: usize,
    /// Whether the answer came from the dense-Cholesky fallback rung.
    pub fallback_dense: bool,
}

impl CgStats {
    /// Collapse the report into a typed [`SolveHealth`].
    ///
    /// Severity order: non-finite values dominate (the numbers cannot be
    /// trusted at all), then breakdowns (frozen RHS carry stale iterates),
    /// then a plain iteration-budget stall.
    pub fn health(&self) -> SolveHealth {
        if self.non_finite || self.rel_residual.iter().any(|r| !r.is_finite()) {
            SolveHealth::NonFinite
        } else if self.breakdowns > 0 {
            SolveHealth::Breakdown
        } else if !self.converged {
            SolveHealth::MaxIters
        } else {
            SolveHealth::Converged
        }
    }

    /// Worst (largest, or non-finite) relative residual across the batch.
    pub fn worst_rel_residual(&self) -> f64 {
        self.rel_residual
            .iter()
            .copied()
            .fold(0.0, |acc, r| if r.is_finite() { acc.max(r) } else { f64::INFINITY })
    }
}

/// Solve A X = B for a batch of right-hand sides with plain CG from a
/// zero initial guess. See [`cg_batch_warm`] for warm starts.
pub fn cg_batch(op: &dyn LinOp, b: &[f64], tol: f64, max_iters: usize) -> (Vec<f64>, CgStats) {
    cg_batch_warm(op, b, None, tol, max_iters)
}

/// Solve A X = B for a batch of right-hand sides with plain CG, optionally
/// warm-started from an initial guess.
///
/// `b` is row-major (batch, len); `x0`, when given, must have the same
/// layout (it is ignored if the length mismatches or it is all zero).
/// Returns the solutions and stats. Systems that converge early are
/// compacted out of the batch (they stop paying operator applications
/// entirely; see `linalg::pcg`) — this mirrors GPyTorch's batched CG
/// semantics that the paper relies on (§B: tol 0.01). Convergence is
/// measured relative to ||b|| regardless of the guess, so a warm and a
/// cold solve stop at the same residual quality.
///
/// This is the identity-preconditioner specialization of
/// [`crate::linalg::pcg::pcg_batch_warm`]; the iterate sequence per RHS is
/// bit-exact with the historical uncompacted plain-CG loop.
pub fn cg_batch_warm(
    op: &dyn LinOp,
    b: &[f64],
    x0: Option<&[f64]>,
    tol: f64,
    max_iters: usize,
) -> (Vec<f64>, CgStats) {
    crate::linalg::pcg::pcg_batch_warm(op, b, x0, None, tol, max_iters)
}

/// Dense matrix as a LinOp (tests + the naive engine's solver reuse).
pub struct DenseOp<'a>(pub &'a crate::linalg::Matrix);

impl LinOp for DenseOp<'_> {
    fn len(&self) -> usize {
        self.0.rows()
    }

    fn apply_batch(&self, x: &[f64], out: &mut [f64], batch: usize) {
        let n = self.len();
        for bi in 0..batch {
            let xi = &x[bi * n..(bi + 1) * n];
            let oi = self.0.matvec(xi);
            out[bi * n..(bi + 1) * n].copy_from_slice(&oi);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::rng::Pcg64;

    fn random_spd(n: usize, seed: u64) -> Matrix {
        let mut rng = Pcg64::new(seed);
        let a = Matrix::from_vec(n, n, rng.normal_vec(n * n));
        let mut spd = a.matmul(&a.transpose());
        spd.add_diag(n as f64 * 0.5);
        spd
    }

    #[test]
    fn solves_dense_system() {
        let n = 40;
        let a = random_spd(n, 1);
        let mut rng = Pcg64::new(2);
        let b = rng.normal_vec(n);
        let (x, stats) = cg_batch(&DenseOp(&a), &b, 1e-10, 500);
        assert!(stats.converged, "rel={:?}", stats.rel_residual);
        let back = a.matvec(&x);
        for i in 0..n {
            assert!((back[i] - b[i]).abs() < 1e-7);
        }
    }

    #[test]
    fn batched_rhs_all_converge() {
        let n = 25;
        let batch = 6;
        let a = random_spd(n, 3);
        let mut rng = Pcg64::new(4);
        let b = rng.normal_vec(n * batch);
        let (x, stats) = cg_batch(&DenseOp(&a), &b, 1e-9, 400);
        assert!(stats.converged);
        for bi in 0..batch {
            let back = a.matvec(&x[bi * n..(bi + 1) * n]);
            for i in 0..n {
                assert!((back[i] - b[bi * n + i]).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn zero_rhs_is_identity_map() {
        let a = random_spd(10, 5);
        let b = vec![0.0; 10];
        let (x, stats) = cg_batch(&DenseOp(&a), &b, 1e-8, 100);
        assert_eq!(stats.iters, 0);
        assert!(x.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn loose_tolerance_converges_fast() {
        let n = 60;
        let a = random_spd(n, 6);
        let mut rng = Pcg64::new(7);
        let b = rng.normal_vec(n);
        let (_, tight) = cg_batch(&DenseOp(&a), &b, 1e-12, 1000);
        let (_, loose) = cg_batch(&DenseOp(&a), &b, 1e-2, 1000);
        assert!(loose.iters < tight.iters);
        assert!(loose.converged);
    }

    #[test]
    fn warm_start_from_random_guess_matches_cold() {
        let n = 35;
        let a = random_spd(n, 11);
        let mut rng = Pcg64::new(12);
        let b = rng.normal_vec(n);
        let guess = rng.normal_vec(n);
        let (cold, cs) = cg_batch(&DenseOp(&a), &b, 1e-10, 500);
        let (warm, ws) = cg_batch_warm(&DenseOp(&a), &b, Some(&guess), 1e-10, 500);
        assert!(cs.converged && ws.converged);
        for i in 0..n {
            assert!((cold[i] - warm[i]).abs() < 1e-6, "i={i}");
        }
        // the warm path pays one extra MVM for the initial residual
        assert_eq!(ws.mvms, ws.iters + 1);
    }

    #[test]
    fn warm_start_from_exact_solution_is_free() {
        let n = 30;
        let a = random_spd(n, 13);
        let mut rng = Pcg64::new(14);
        let b = rng.normal_vec(n);
        let (x, _) = cg_batch(&DenseOp(&a), &b, 1e-12, 1000);
        let (x2, stats) = cg_batch_warm(&DenseOp(&a), &b, Some(&x), 1e-8, 1000);
        assert!(stats.iters <= 2, "iters={}", stats.iters);
        assert!(stats.converged);
        for i in 0..n {
            assert!((x[i] - x2[i]).abs() < 1e-6);
        }
    }

    #[test]
    fn warm_start_ignores_mismatched_or_zero_guess() {
        let n = 12;
        let a = random_spd(n, 15);
        let mut rng = Pcg64::new(16);
        let b = rng.normal_vec(n);
        let (cold, cs) = cg_batch(&DenseOp(&a), &b, 1e-10, 200);
        let short = vec![1.0; n - 1];
        let (w1, s1) = cg_batch_warm(&DenseOp(&a), &b, Some(&short), 1e-10, 200);
        let zeros = vec![0.0; n];
        let (w2, s2) = cg_batch_warm(&DenseOp(&a), &b, Some(&zeros), 1e-10, 200);
        assert_eq!(cold, w1);
        assert_eq!(cold, w2);
        assert_eq!(cs.mvms, s1.mvms);
        assert_eq!(cs.mvms, s2.mvms);
    }

    #[test]
    fn per_rhs_iteration_counts_reflect_warmth() {
        let n = 28;
        let batch = 2;
        let a = random_spd(n, 17);
        let mut rng = Pcg64::new(18);
        let b = rng.normal_vec(n * batch);
        // solve the first element tightly, leave the second cold
        let (x, _) = cg_batch(&DenseOp(&a), &b[..n], 1e-12, 500);
        let mut guess = vec![0.0; n * batch];
        guess[..n].copy_from_slice(&x);
        let (_, stats) = cg_batch_warm(&DenseOp(&a), &b, Some(&guess), 1e-9, 500);
        assert_eq!(stats.iters_per_rhs.len(), batch);
        assert!(
            stats.iters_per_rhs[0] < stats.iters_per_rhs[1],
            "warm element should be cheaper: {:?}",
            stats.iters_per_rhs
        );
    }

    #[test]
    fn mixed_convergence_freezes_done_systems() {
        // One trivial RHS (eigvec direction) + one hard RHS.
        let n = 30;
        let a = random_spd(n, 8);
        let mut b = vec![0.0; 2 * n];
        b[0] = 1.0; // converges in a few iters along e0? still fine
        let mut rng = Pcg64::new(9);
        for i in 0..n {
            b[n + i] = rng.normal();
        }
        let (x, stats) = cg_batch(&DenseOp(&a), &b, 1e-9, 500);
        assert!(stats.converged);
        for bi in 0..2 {
            let back = a.matvec(&x[bi * n..(bi + 1) * n]);
            for i in 0..n {
                assert!((back[i] - b[bi * n + i]).abs() < 1e-6);
            }
        }
    }
}
