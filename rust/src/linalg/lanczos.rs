//! Lanczos tridiagonalization and stochastic Lanczos quadrature (SLQ).
//!
//! SLQ estimates log det(A) of the masked latent-Kronecker operator from a
//! handful of Rademacher probes: logdet(A) ~ (N / p) sum_i e1^T log(T_i) e1
//! with T_i the Lanczos tridiagonal for probe z_i. This is the GPyTorch
//! inference stack (Gardner et al. 2018) the paper builds on, rebuilt on
//! our own operator/eigh substrate.

use super::cg::LinOp;
use super::eigh::tridiag_eigh;

/// Lanczos tridiagonalization with full reorthogonalization.
///
/// Returns (alpha, beta): diagonal (k) and off-diagonal (k-1) of T_k.
/// Full reorthogonalization is affordable at the k <= 32 Krylov sizes used
/// for quadrature and keeps the Ritz values honest in double precision.
pub fn lanczos(op: &dyn LinOp, z: &[f64], k: usize) -> (Vec<f64>, Vec<f64>) {
    let n = op.len();
    debug_assert_eq!(z.len(), n);
    let k = k.min(n.max(1));

    let znorm = super::matrix::dot(z, z).sqrt().max(1e-300);
    let mut q: Vec<f64> = z.iter().map(|v| v / znorm).collect();
    let mut q_prev = vec![0.0; n];
    let mut basis: Vec<Vec<f64>> = Vec::with_capacity(k);
    let mut alphas = Vec::with_capacity(k);
    let mut betas = Vec::with_capacity(k.saturating_sub(1));
    let mut aq = vec![0.0; n];
    let mut beta_prev = 0.0;

    for i in 0..k {
        op.apply_batch(&q, &mut aq, 1);
        let alpha = super::matrix::dot(&q, &aq);
        alphas.push(alpha);
        if i + 1 == k {
            break;
        }
        let mut w: Vec<f64> = (0..n)
            .map(|j| aq[j] - alpha * q[j] - beta_prev * q_prev[j])
            .collect();
        basis.push(q.clone());
        // Two rounds of classical Gram-Schmidt against the stored basis.
        for _ in 0..2 {
            for b in &basis {
                let c = super::matrix::dot(b, &w);
                super::matrix::axpy(-c, b, &mut w);
            }
        }
        let beta = super::matrix::dot(&w, &w).sqrt();
        if beta < 1e-12 {
            // Invariant subspace exhausted: T is effectively (i+1)x(i+1).
            break;
        }
        betas.push(beta);
        q_prev = std::mem::replace(&mut q, w.iter().map(|v| v / beta).collect());
        beta_prev = beta;
    }

    (alphas, betas)
}

/// SLQ estimate of log det(A) from `probes` (each a Rademacher vector).
///
/// `probes` is row-major (p, N). The estimate is for the FULL-space
/// operator; callers subtract padding corrections (see gp::lkgp).
pub fn slq_logdet(op: &dyn LinOp, probes: &[f64], k: usize) -> f64 {
    let n = op.len();
    let p = probes.len() / n;
    assert!(p > 0, "need at least one probe");
    let threads = crate::util::num_threads().min(p);
    let quad_one = |z: &[f64]| -> f64 {
        let (alphas, betas) = lanczos(op, z, k);
        let (evals, evecs) = tridiag_eigh(&alphas, &betas);
        let mut quad = 0.0;
        for (j, &ev) in evals.iter().enumerate() {
            let w = evecs[(0, j)] * evecs[(0, j)];
            quad += w * ev.max(1e-300).ln();
        }
        quad
    };
    // Probes are independent Lanczos runs — parallelize across them
    // (§Perf: the logdet estimate is ~40% of an MLL evaluation).
    let total: f64 = if threads <= 1 || p == 1 {
        (0..p).map(|pi| quad_one(&probes[pi * n..(pi + 1) * n])).sum()
    } else {
        let chunk = p.div_ceil(threads);
        let partials = std::sync::Mutex::new(vec![0.0; threads]);
        std::thread::scope(|scope| {
            for ti in 0..threads {
                let partials = &partials;
                let quad_one = &quad_one;
                scope.spawn(move || {
                    crate::linalg::matrix::without_nested_parallelism(|| {
                        let mut local = 0.0;
                        for pi in (ti * chunk)..((ti + 1) * chunk).min(p) {
                            local += quad_one(&probes[pi * n..(pi + 1) * n]);
                        }
                        partials.lock().unwrap()[ti] = local;
                    });
                });
            }
        });
        partials.into_inner().unwrap().iter().sum()
    };
    n as f64 * total / p as f64
}

/// Hutchinson trace estimate of A (not A^{-1}): mean_i z_i^T A z_i.
/// Exposed for ablation benches and tests.
pub fn hutchinson_trace(op: &dyn LinOp, probes: &[f64]) -> f64 {
    let n = op.len();
    let p = probes.len() / n;
    let mut az = vec![0.0; n];
    let mut total = 0.0;
    for pi in 0..p {
        let z = &probes[pi * n..(pi + 1) * n];
        op.apply_batch(z, &mut az, 1);
        total += super::matrix::dot(z, &az);
    }
    total / p as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::cg::DenseOp;
    use crate::linalg::{cholesky, Matrix};
    use crate::rng::Pcg64;

    fn random_spd(n: usize, seed: u64) -> Matrix {
        let mut rng = Pcg64::new(seed);
        let a = Matrix::from_vec(n, n, rng.normal_vec(n * n));
        let mut spd = a.matmul(&a.transpose());
        spd.add_diag(n as f64 * 0.3);
        spd
    }

    #[test]
    fn lanczos_t_matches_rayleigh_quotients() {
        let n = 20;
        let a = random_spd(n, 1);
        let mut rng = Pcg64::new(2);
        let z = rng.normal_vec(n);
        let (alphas, betas) = lanczos(&DenseOp(&a), &z, 8);
        assert_eq!(alphas.len(), 8);
        assert_eq!(betas.len(), 7);
        // Ritz values lie within the spectrum bounds.
        let (evals, _) = crate::linalg::eigh::jacobi_eigh(&a, 30);
        let (lo, hi) = (
            evals.iter().cloned().fold(f64::INFINITY, f64::min),
            evals.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
        );
        let (ritz, _) = tridiag_eigh(&alphas, &betas);
        for r in ritz {
            assert!(r > lo - 1e-8 && r < hi + 1e-8);
        }
    }

    #[test]
    fn full_krylov_recovers_exact_logdet() {
        let n = 12;
        let a = random_spd(n, 3);
        let l = cholesky::cholesky(&a).unwrap();
        let want = cholesky::chol_logdet(&l);
        let mut rng = Pcg64::new(4);
        let probes = rng.rademacher_vec(n * 48);
        let got = slq_logdet(&DenseOp(&a), &probes, n);
        assert!(
            (got - want).abs() / want.abs() < 0.05,
            "got={got} want={want}"
        );
    }

    #[test]
    fn slq_tightens_with_probes() {
        let n = 24;
        let a = random_spd(n, 5);
        let l = cholesky::cholesky(&a).unwrap();
        let want = cholesky::chol_logdet(&l);
        let mut errs = Vec::new();
        for p in [4usize, 64] {
            // average over independent probe draws to reduce flake
            let mut err_sum = 0.0;
            for s in 0..5 {
                let mut rng = Pcg64::new(100 + s);
                let probes = rng.rademacher_vec(n * p);
                let got = slq_logdet(&DenseOp(&a), &probes, 16);
                err_sum += (got - want).abs();
            }
            errs.push(err_sum / 5.0);
        }
        assert!(errs[1] <= errs[0] * 1.5, "errs={errs:?}");
    }

    #[test]
    fn hutchinson_estimates_trace() {
        let n = 16;
        let a = random_spd(n, 7);
        let trace: f64 = (0..n).map(|i| a[(i, i)]).sum();
        let mut rng = Pcg64::new(8);
        let probes = rng.rademacher_vec(n * 256);
        let got = hutchinson_trace(&DenseOp(&a), &probes);
        assert!((got - trace).abs() / trace < 0.1);
    }

    #[test]
    fn identity_logdet_is_zero() {
        let a = Matrix::eye(10);
        let mut rng = Pcg64::new(9);
        let probes = rng.rademacher_vec(10 * 4);
        let got = slq_logdet(&DenseOp(&a), &probes, 6);
        assert!(got.abs() < 1e-8);
    }

    #[test]
    fn early_breakdown_handled() {
        // Rank-deficient direction: operator with repeated eigenvalues makes
        // Lanczos terminate early; must not panic and still be finite.
        let mut a = Matrix::eye(8);
        a.scale(2.0);
        let mut rng = Pcg64::new(10);
        let probes = rng.rademacher_vec(8 * 2);
        let got = slq_logdet(&DenseOp(&a), &probes, 8);
        assert!((got - 8.0 * 2f64.ln()).abs() < 1e-6);
    }
}
