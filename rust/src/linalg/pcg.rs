//! Batched preconditioned conjugate gradients with active-set compaction.
//!
//! This is the solver core behind both `cg_batch_warm` (identity
//! preconditioner — the iterate sequence is bit-exact with the historical
//! plain-CG implementation) and the latent-Kronecker PCG path
//! (`gp::operator::LatentKronPrecond`). Two properties matter here:
//!
//! * **Compaction.** Converged right-hand sides are gathered OUT of the
//!   batch before every `apply_batch`, so a frozen system never pays
//!   another operator application. With warm starts most of the 9–33
//!   training RHS converge in 0–2 iterations; previously they kept burning
//!   full Kronecker MVMs every iteration. `CgStats::mvm_rows` counts the
//!   per-RHS operator rows actually applied, making the saving observable.
//! * **Bit-exactness.** Each RHS's update sequence is identical to the
//!   uncompacted loop (operators apply rows independently), and with no /
//!   identity preconditioner every scalar (alpha, beta, residual norms)
//!   is computed from bitwise-identical inputs, so `pcg_batch_warm(...,
//!   None, ...)` reproduces the old `cg_batch_warm` exactly.

use super::cg::{CgStats, LinOp};

/// A symmetric positive-definite preconditioner: z = M⁻¹ r, batched.
///
/// Implementations must apply rows independently (`z[b]` depends only on
/// `r[b]`) so the solver can compact converged systems out of the batch.
pub trait Preconditioner: Sync {
    /// z[b] = M⁻¹ r[b] for each batch row (row-major, `len`-dim rows).
    fn apply_batch(&self, r: &[f64], z: &mut [f64], batch: usize);
}

/// The zero-cost identity preconditioner (z = r). PCG with this is
/// bit-exact with plain CG; it exists so callers can hold a
/// `&dyn Preconditioner` uniformly.
pub struct IdentityPrecond;

impl Preconditioner for IdentityPrecond {
    fn apply_batch(&self, r: &[f64], z: &mut [f64], _batch: usize) {
        z.copy_from_slice(r);
    }
}

/// Solve A X = B for a batch of right-hand sides with (preconditioned)
/// conjugate gradients, optionally warm-started from `x0`.
///
/// `b` is row-major (batch, len); `x0`, when given, must have the same
/// layout (ignored if the length mismatches or it is all zero).
/// `precond` of `None` is plain CG — bit-exact with the historical
/// `cg_batch_warm` (an explicit [`IdentityPrecond`] lands on the same
/// iterates through the preconditioned code path). Convergence is measured
/// on the TRUE residual ‖b − A x‖ / ‖b‖ regardless of preconditioning, so
/// every configuration stops at the same residual quality (paper §B:
/// tol 0.01).
pub fn pcg_batch_warm(
    op: &dyn LinOp,
    b: &[f64],
    x0: Option<&[f64]>,
    precond: Option<&dyn Preconditioner>,
    tol: f64,
    max_iters: usize,
) -> (Vec<f64>, CgStats) {
    let n = op.len();
    let batch = if n == 0 { 0 } else { b.len() / n };
    debug_assert_eq!(b.len(), batch * n);
    // An IdentityPrecond behind the trait object still produces identical
    // scalars (its z is a bitwise copy of r), it just pays the copy.
    let ident = precond.is_none();

    let (mut x, warm) = match x0 {
        // lint: allow(float_eq) — exact-zero test on the warm guess: an
        // all-zero vector is the cold-start sentinel, and any nonzero bit
        // pattern (however tiny) is a legitimate guess worth one MVM.
        Some(g) if g.len() == b.len() && g.iter().any(|&v| v != 0.0) => (g.to_vec(), true),
        _ => (vec![0.0; b.len()], false),
    };
    let mut r = b.to_vec();
    let mut warm_mvms = 0;
    let mut mvm_rows = 0usize;
    if warm {
        // r = b - A x0 (one extra fused batch MVM over every row).
        let mut ax = vec![0.0; b.len()];
        op.apply_batch(&x, &mut ax, batch);
        warm_mvms = 1;
        mvm_rows += batch;
        for (ri, ai) in r.iter_mut().zip(&ax) {
            *ri -= ai;
        }
    }

    // p0 = z0 = M⁻¹ r0 (z aliases r conceptually for plain CG).
    let mut p = match precond {
        None => r.clone(),
        Some(m) => {
            let mut z0 = vec![0.0; b.len()];
            if batch > 0 {
                m.apply_batch(&r, &mut z0, batch);
            }
            z0
        }
    };

    let bnorm: Vec<f64> = (0..batch)
        .map(|bi| norm(&b[bi * n..(bi + 1) * n]).max(1e-300))
        .collect();
    // rs tracks ‖r‖² (convergence); rz tracks rᵀz (alpha/beta). For plain
    // CG the two coincide bitwise.
    let mut rs: Vec<f64> = (0..batch)
        .map(|bi| {
            let rb = &r[bi * n..(bi + 1) * n];
            crate::linalg::matrix::dot(rb, rb)
        })
        .collect();
    let mut rz: Vec<f64> = if ident {
        rs.clone()
    } else {
        (0..batch)
            .map(|bi| {
                crate::linalg::matrix::dot(&r[bi * n..(bi + 1) * n], &p[bi * n..(bi + 1) * n])
            })
            .collect()
    };

    // Compaction scratch: gathered active rows of p / Ap / r / z.
    let mut pc: Vec<f64> = vec![0.0; b.len()];
    let mut apc: Vec<f64> = vec![0.0; b.len()];
    let mut zc: Vec<f64> = if ident { Vec::new() } else { vec![0.0; b.len()] };

    let mut iters = 0;
    let mut iters_per_rhs = vec![0usize; batch];
    // RHS frozen by a Krylov breakdown (pᵀAp ≤ 0 or non-finite): they keep
    // their last iterate and stop paying MVMs, but they are NOT converged —
    // reported via CgStats::breakdowns so callers can escalate.
    let mut broken = vec![false; batch];
    for _ in 0..max_iters {
        let active: Vec<usize> = (0..batch)
            .filter(|&bi| rs[bi].sqrt() > tol * bnorm[bi])
            .collect();
        if active.is_empty() {
            break;
        }
        iters += 1;
        let k = active.len();
        // Gather active search directions into a dense sub-batch, apply
        // the operator once over exactly those rows.
        for (ai, &bi) in active.iter().enumerate() {
            pc[ai * n..(ai + 1) * n].copy_from_slice(&p[bi * n..(bi + 1) * n]);
        }
        op.apply_batch(&pc[..k * n], &mut apc[..k * n], k);
        mvm_rows += k;

        // x/r updates per active RHS (scatter back by row index).
        let mut frozen = vec![false; k];
        for (ai, &bi) in active.iter().enumerate() {
            iters_per_rhs[bi] += 1;
            let (pb, apb) = (&pc[ai * n..(ai + 1) * n], &apc[ai * n..(ai + 1) * n]);
            let denom = crate::linalg::matrix::dot(pb, apb);
            if denom <= 0.0 || !denom.is_finite() {
                // Operator not PD along p (should not happen); freeze the
                // iterate and flag the breakdown. rs is zeroed only to
                // compact this RHS out of future applies — the true
                // residual (still in r) is restored for the final report.
                rs[bi] = 0.0;
                frozen[ai] = true;
                broken[bi] = true;
                continue;
            }
            let alpha = rz[bi] / denom;
            crate::linalg::matrix::axpy(alpha, pb, &mut x[bi * n..(bi + 1) * n]);
            crate::linalg::matrix::axpy(-alpha, apb, &mut r[bi * n..(bi + 1) * n]);
            let rb = &r[bi * n..(bi + 1) * n];
            rs[bi] = crate::linalg::matrix::dot(rb, rb);
        }

        // z = M⁻¹ r over the same active set (one batched apply), then the
        // beta / search-direction update.
        if let Some(m) = precond {
            for (ai, &bi) in active.iter().enumerate() {
                pc[ai * n..(ai + 1) * n].copy_from_slice(&r[bi * n..(bi + 1) * n]);
            }
            m.apply_batch(&pc[..k * n], &mut zc[..k * n], k);
        }
        for (ai, &bi) in active.iter().enumerate() {
            if frozen[ai] {
                continue;
            }
            let rznew = if ident {
                rs[bi]
            } else {
                crate::linalg::matrix::dot(
                    &pc[ai * n..(ai + 1) * n],
                    &zc[ai * n..(ai + 1) * n],
                )
            };
            let beta = rznew / rz[bi];
            rz[bi] = rznew;
            if ident {
                // Split borrows: p and r are distinct buffers.
                let rb = &r[bi * n..(bi + 1) * n];
                crate::linalg::matrix::axpby(1.0, rb, beta, &mut p[bi * n..(bi + 1) * n]);
            } else {
                let zb = &zc[ai * n..(ai + 1) * n];
                crate::linalg::matrix::axpby(1.0, zb, beta, &mut p[bi * n..(bi + 1) * n]);
            }
        }
    }

    // Broken-down RHS report their TRUE residual (rs was zeroed only for
    // compaction; r still holds b − A x at the freeze point).
    let rel: Vec<f64> = (0..batch)
        .map(|bi| {
            if broken[bi] {
                norm(&r[bi * n..(bi + 1) * n]) / bnorm[bi]
            } else {
                rs[bi].sqrt() / bnorm[bi]
            }
        })
        .collect();
    let breakdowns = broken.iter().filter(|&&f| f).count();
    let non_finite =
        rel.iter().any(|v| !v.is_finite()) || x.iter().any(|v| !v.is_finite());
    let converged =
        breakdowns == 0 && !non_finite && rel.iter().all(|&r| r <= tol * 1.0001);
    (
        x,
        CgStats {
            iters,
            iters_per_rhs,
            rel_residual: rel,
            converged,
            mvms: iters + warm_mvms,
            mvm_rows,
            breakdowns,
            non_finite,
            escalations: 0,
            fallback_dense: false,
        },
    )
}

fn norm(v: &[f64]) -> f64 {
    crate::linalg::matrix::dot(v, v).sqrt()
}

// ---------------------------------------------------------------------------
// Iterative refinement (mixed-precision outer loop)

/// Solve statistics for [`refined_solve`]. Shapes mirror [`CgStats`] so
/// observability plumbing (scheduler stats, bench JSON) can treat both
/// uniformly via [`RefineStats::to_cg_stats`].
#[derive(Clone, Debug)]
pub struct RefineStats {
    /// Outer refinement sweeps executed (each = one fast solve + one
    /// exact-operator residual recompute).
    pub outer_iters: usize,
    /// Total inner (fast-operator) CG iterations across sweeps.
    pub inner_iters: usize,
    /// Inner iterations per RHS, summed across sweeps.
    pub iters_per_rhs: Vec<usize>,
    /// Final relative residual per RHS, measured against the EXACT
    /// operator — this is what makes the f32 path's answers f64-grade.
    pub rel_residual: Vec<f64>,
    pub converged: bool,
    /// Batched operator applications, exact + fast.
    pub mvms: usize,
    /// Per-RHS operator rows applied, exact + fast.
    pub mvm_rows: usize,
    /// Inner-solve Krylov breakdowns that the refinement could NOT absorb.
    /// An inner breakdown followed by exact-residual convergence is healthy
    /// (the exact residual is the truth), so this is zeroed on convergence.
    pub breakdowns: usize,
    /// Whether any exact residual or iterate went non-finite.
    pub non_finite: bool,
}

impl RefineStats {
    /// Collapse into the [`CgStats`] shape (inner iterations count as the
    /// iteration budget; residuals are the exact-operator ones).
    pub fn to_cg_stats(&self) -> CgStats {
        CgStats {
            iters: self.inner_iters,
            iters_per_rhs: self.iters_per_rhs.clone(),
            rel_residual: self.rel_residual.clone(),
            converged: self.converged,
            mvms: self.mvms,
            mvm_rows: self.mvm_rows,
            breakdowns: self.breakdowns,
            non_finite: self.non_finite,
            escalations: 0,
            fallback_dense: false,
        }
    }
}

/// Mixed-precision iterative refinement: drive the residual of the EXACT
/// (f64) operator below `tol` while doing the iteration-heavy work on a
/// cheap surrogate operator (f32-storage Kronecker factors —
/// `gp::operator::MaskedKronOpF32`).
///
/// Classic scheme (Wilkinson; arXiv 2312.15305 for tensor-product GPs):
///
/// ```text
/// x ← x0
/// r ← b − A_exact x
/// while ‖r‖ > tol·‖b‖:   d ← solve(A_fast, r)   (inner_tol, PCG)
///                        x ← x + d
///                        r ← b − A_exact x      (one exact batched MVM)
/// ```
///
/// Converged right-hand sides are compacted out of the outer loop exactly
/// like the inner PCG compacts its batch, so a mostly-warm batch pays one
/// exact MVM row per sweep for the stragglers only. The preconditioner
/// (built for the exact operator) is applied to the fast solves — any SPD
/// preconditioner is valid there, it only changes iteration counts.
///
/// Caveat: each sweep contracts the error by roughly the f32 rounding of
/// the factors times the system's conditioning; `tol` far below that
/// contraction floor may exhaust `max_outer` without converging (reported
/// honestly in `RefineStats::converged` / `rel_residual`, never asserted).
#[allow(clippy::too_many_arguments)]
pub fn refined_solve(
    exact: &dyn LinOp,
    fast: &dyn LinOp,
    b: &[f64],
    x0: Option<&[f64]>,
    precond: Option<&dyn Preconditioner>,
    tol: f64,
    inner_tol: f64,
    max_outer: usize,
    max_inner: usize,
) -> (Vec<f64>, RefineStats) {
    let n = exact.len();
    debug_assert_eq!(fast.len(), n, "exact/fast operator dimension mismatch");
    let batch = if n == 0 { 0 } else { b.len() / n };
    debug_assert_eq!(b.len(), batch * n);

    let (mut x, warm) = match x0 {
        // lint: allow(float_eq) — exact-zero test on the warm guess: an
        // all-zero vector is the cold-start sentinel, and any nonzero bit
        // pattern (however tiny) is a legitimate guess worth one MVM.
        Some(g) if g.len() == b.len() && g.iter().any(|&v| v != 0.0) => (g.to_vec(), true),
        _ => (vec![0.0; b.len()], false),
    };
    let mut r = b.to_vec();
    let mut mvms = 0usize;
    let mut mvm_rows = 0usize;
    if warm {
        let mut ax = vec![0.0; b.len()];
        exact.apply_batch(&x, &mut ax, batch);
        mvms += 1;
        mvm_rows += batch;
        for (ri, ai) in r.iter_mut().zip(&ax) {
            *ri -= ai;
        }
    }
    let bnorm: Vec<f64> = (0..batch)
        .map(|bi| norm(&b[bi * n..(bi + 1) * n]).max(1e-300))
        .collect();

    let mut outer_iters = 0usize;
    let mut inner_iters = 0usize;
    let mut iters_per_rhs = vec![0usize; batch];
    let mut inner_breakdowns = 0usize;
    // Compaction scratch: active rows of r / the correction / A x.
    let mut rc = vec![0.0; b.len()];
    let mut axc = vec![0.0; b.len()];
    for _ in 0..max_outer {
        let active: Vec<usize> = (0..batch)
            .filter(|&bi| norm(&r[bi * n..(bi + 1) * n]) > tol * bnorm[bi])
            .collect();
        if active.is_empty() {
            break;
        }
        outer_iters += 1;
        let k = active.len();
        for (ai, &bi) in active.iter().enumerate() {
            rc[ai * n..(ai + 1) * n].copy_from_slice(&r[bi * n..(bi + 1) * n]);
        }
        // Correction solve on the fast operator (cold start: the RHS is a
        // residual, there is no meaningful guess for its correction).
        let (d, st) = pcg_batch_warm(fast, &rc[..k * n], None, precond, inner_tol, max_inner);
        inner_iters += st.iters;
        mvms += st.mvms;
        mvm_rows += st.mvm_rows;
        inner_breakdowns += st.breakdowns;
        for (ai, &bi) in active.iter().enumerate() {
            iters_per_rhs[bi] += st.iters_per_rhs[ai];
            crate::linalg::matrix::axpy(1.0, &d[ai * n..(ai + 1) * n], &mut x[bi * n..(bi + 1) * n]);
        }
        // Exact residual recompute over the active rows only (converged
        // rows kept their x, hence their r).
        for (ai, &bi) in active.iter().enumerate() {
            rc[ai * n..(ai + 1) * n].copy_from_slice(&x[bi * n..(bi + 1) * n]);
        }
        exact.apply_batch(&rc[..k * n], &mut axc[..k * n], k);
        mvms += 1;
        mvm_rows += k;
        for (ai, &bi) in active.iter().enumerate() {
            let (rb, (bb, ab)) = (
                &mut r[bi * n..(bi + 1) * n],
                (&b[bi * n..(bi + 1) * n], &axc[ai * n..(ai + 1) * n]),
            );
            for i in 0..n {
                rb[i] = bb[i] - ab[i];
            }
        }
    }

    let rel: Vec<f64> = (0..batch)
        .map(|bi| norm(&r[bi * n..(bi + 1) * n]) / bnorm[bi])
        .collect();
    let non_finite =
        rel.iter().any(|v| !v.is_finite()) || x.iter().any(|v| !v.is_finite());
    let converged = !non_finite && rel.iter().all(|&v| v <= tol * 1.0001);
    (
        x,
        RefineStats {
            outer_iters,
            inner_iters,
            iters_per_rhs,
            rel_residual: rel,
            converged,
            mvms,
            mvm_rows,
            // Absorbed breakdowns are healthy: the exact residual is the
            // ground truth, so only report them when the solve failed.
            breakdowns: if converged { 0 } else { inner_breakdowns },
            non_finite,
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::cg::{cg_batch, cg_batch_warm, DenseOp};
    use crate::linalg::Matrix;
    use crate::rng::Pcg64;

    fn random_spd(n: usize, seed: u64) -> Matrix {
        let mut rng = Pcg64::new(seed);
        let a = Matrix::from_vec(n, n, rng.normal_vec(n * n));
        let mut spd = a.matmul(&a.transpose());
        spd.add_diag(n as f64 * 0.5);
        spd
    }

    /// Jacobi (diagonal) preconditioner for dense SPD tests.
    struct Diag(Vec<f64>);

    impl Preconditioner for Diag {
        fn apply_batch(&self, r: &[f64], z: &mut [f64], batch: usize) {
            let n = self.0.len();
            for bi in 0..batch {
                for i in 0..n {
                    z[bi * n + i] = r[bi * n + i] / self.0[i];
                }
            }
        }
    }

    #[test]
    fn identity_precond_bit_exact_with_plain_cg() {
        let n = 30;
        let batch = 4;
        let a = random_spd(n, 1);
        let mut rng = Pcg64::new(2);
        let b = rng.normal_vec(n * batch);
        let guess = rng.normal_vec(n * batch);
        for x0 in [None, Some(&guess[..])] {
            let (cg_x, cg_s) = cg_batch_warm(&DenseOp(&a), &b, x0, 1e-9, 500);
            let (pcg_x, pcg_s) =
                pcg_batch_warm(&DenseOp(&a), &b, x0, Some(&IdentityPrecond), 1e-9, 500);
            assert_eq!(cg_x, pcg_x, "iterates diverged (warm={})", x0.is_some());
            assert_eq!(cg_s.iters, pcg_s.iters);
            assert_eq!(cg_s.iters_per_rhs, pcg_s.iters_per_rhs);
            assert_eq!(cg_s.rel_residual, pcg_s.rel_residual);
            assert_eq!(cg_s.mvms, pcg_s.mvms);
            assert_eq!(cg_s.mvm_rows, pcg_s.mvm_rows);
        }
    }

    #[test]
    fn jacobi_precond_converges_to_same_solution() {
        // Badly row/column-scaled SPD system (D A D): plain CG crawls,
        // the Jacobi preconditioner restores the base conditioning. Both
        // must converge to the same solution.
        let n = 40;
        let base = random_spd(n, 3);
        let mut sym = base.clone();
        for i in 0..n {
            for j in 0..n {
                let si = 10f64.powi((i % 5) as i32);
                let sj = 10f64.powi((j % 5) as i32);
                sym[(i, j)] = base[(i, j)] * si * sj;
            }
        }
        let diag: Vec<f64> = (0..n).map(|i| sym[(i, i)]).collect();
        let mut rng = Pcg64::new(4);
        let b = rng.normal_vec(n);
        let (plain, ps) = cg_batch(&DenseOp(&sym), &b, 1e-10, 4000);
        let (pcgx, ss) =
            pcg_batch_warm(&DenseOp(&sym), &b, None, Some(&Diag(diag)), 1e-10, 4000);
        assert!(ps.converged && ss.converged);
        assert!(
            ss.iters <= ps.iters,
            "jacobi {} vs plain {}",
            ss.iters,
            ps.iters
        );
        // Compare through the residual scale of the worst-conditioned rows.
        let back_p = sym.matvec(&plain);
        let back_q = sym.matvec(&pcgx);
        for i in 0..n {
            let scale = diag[i].abs().max(1.0);
            assert!((back_p[i] - b[i]).abs() / scale < 1e-4, "plain i={i}");
            assert!((back_q[i] - b[i]).abs() / scale < 1e-4, "pcg i={i}");
        }
    }

    #[test]
    fn compaction_stops_charging_converged_rhs() {
        let n = 25;
        let a = random_spd(n, 5);
        let mut rng = Pcg64::new(6);
        // one RHS pre-solved (converges at iteration 0), one cold
        let b_cold = rng.normal_vec(n);
        let (x_exact, _) = cg_batch(&DenseOp(&a), &b_cold, 1e-12, 1000);
        let mut b = vec![0.0; 2 * n];
        b[..n].copy_from_slice(&b_cold);
        let mut rng2 = Pcg64::new(7);
        b[n..].copy_from_slice(&rng2.normal_vec(n));
        let mut guess = vec![0.0; 2 * n];
        guess[..n].copy_from_slice(&x_exact);
        let (_, stats) = cg_batch_warm(&DenseOp(&a), &b, Some(&guess), 1e-8, 1000);
        // warm residual apply charges both rows once; afterwards only the
        // cold RHS pays per-iteration rows
        let expected = 2 + stats.iters_per_rhs.iter().sum::<usize>();
        assert_eq!(stats.mvm_rows, expected, "stats={stats:?}");
        assert!(stats.iters_per_rhs[0] <= 1);
        assert!(stats.iters_per_rhs[1] > stats.iters_per_rhs[0]);
    }

    #[test]
    fn mvm_rows_equals_batch_times_iters_when_uniform() {
        let n = 20;
        let batch = 3;
        let a = random_spd(n, 8);
        let mut rng = Pcg64::new(9);
        let b = rng.normal_vec(n * batch);
        let (_, stats) = cg_batch(&DenseOp(&a), &b, 1e-9, 500);
        assert_eq!(
            stats.mvm_rows,
            stats.iters_per_rhs.iter().sum::<usize>(),
            "cold solve rows must equal summed per-RHS iterations"
        );
        assert!(stats.mvm_rows <= batch * stats.iters);
    }

    /// f32-round a dense matrix (storage rounding surrogate for tests).
    fn round_f32(a: &Matrix) -> Matrix {
        Matrix::from_vec(
            a.rows(),
            a.cols(),
            a.data().iter().map(|&v| v as f32 as f64).collect(),
        )
    }

    #[test]
    fn refinement_recovers_exact_residual_through_rounded_operator() {
        let n = 32;
        let batch = 3;
        let exact = random_spd(n, 20);
        let fast = round_f32(&exact);
        let mut rng = Pcg64::new(21);
        let b = rng.normal_vec(n * batch);
        let tol = 1e-10;
        let (x, st) = refined_solve(
            &DenseOp(&exact),
            &DenseOp(&fast),
            &b,
            None,
            None,
            tol,
            1e-4,
            20,
            500,
        );
        assert!(st.converged, "stats={st:?}");
        assert!(st.outer_iters >= 1);
        // The residual claim is against the EXACT operator.
        for bi in 0..batch {
            let ax = exact.matvec(&x[bi * n..(bi + 1) * n]);
            let bb = &b[bi * n..(bi + 1) * n];
            let rn: f64 = ax
                .iter()
                .zip(bb)
                .map(|(a, b)| (b - a) * (b - a))
                .sum::<f64>()
                .sqrt();
            let bn: f64 = bb.iter().map(|v| v * v).sum::<f64>().sqrt();
            assert!(rn <= tol * 1.001 * bn, "rhs {bi}: rel={}", rn / bn);
        }
        // And agrees with the pure-f64 solve well below the f32 scale.
        let (oracle, _) = pcg_batch_warm(&DenseOp(&exact), &b, None, None, 1e-12, 2000);
        for (a, o) in x.iter().zip(&oracle) {
            assert!((a - o).abs() < 1e-7, "{a} vs {o}");
        }
    }

    #[test]
    fn refinement_warm_start_and_compaction() {
        let n = 24;
        let exact = random_spd(n, 22);
        let fast = round_f32(&exact);
        let mut rng = Pcg64::new(23);
        let b_cold = rng.normal_vec(n);
        // Pre-solve one RHS; stack it with a cold one.
        let (x_exact, _) = pcg_batch_warm(&DenseOp(&exact), &b_cold, None, None, 1e-12, 2000);
        let mut b = vec![0.0; 2 * n];
        b[..n].copy_from_slice(&b_cold);
        b[n..].copy_from_slice(&rng.normal_vec(n));
        let mut guess = vec![0.0; 2 * n];
        guess[..n].copy_from_slice(&x_exact);
        let (x, st) = refined_solve(
            &DenseOp(&exact),
            &DenseOp(&fast),
            &b,
            Some(&guess),
            None,
            1e-8,
            1e-4,
            20,
            500,
        );
        assert!(st.converged, "stats={st:?}");
        // The warm RHS is converged on arrival: zero inner iterations.
        assert_eq!(st.iters_per_rhs[0], 0, "stats={st:?}");
        assert!(st.iters_per_rhs[1] > 0);
        for (a, e) in x[..n].iter().zip(&x_exact) {
            assert!((a - e).abs() < 1e-9, "warm row must be untouched-ish");
        }
    }

    #[test]
    fn refinement_with_jacobi_precond_converges() {
        let n = 28;
        let exact = random_spd(n, 24);
        let fast = round_f32(&exact);
        let diag: Vec<f64> = (0..n).map(|i| exact[(i, i)]).collect();
        let mut rng = Pcg64::new(25);
        let b = rng.normal_vec(n);
        let (x, st) = refined_solve(
            &DenseOp(&exact),
            &DenseOp(&fast),
            &b,
            None,
            Some(&Diag(diag)),
            1e-9,
            1e-4,
            20,
            500,
        );
        assert!(st.converged, "stats={st:?}");
        let ax = exact.matvec(&x);
        let bn: f64 = b.iter().map(|v| v * v).sum::<f64>().sqrt();
        let rn: f64 = ax.iter().zip(&b).map(|(a, b)| (b - a) * (b - a)).sum::<f64>().sqrt();
        assert!(rn <= 1e-9 * 1.001 * bn);
    }

    #[test]
    fn refinement_empty_and_zero_rhs() {
        let a = random_spd(8, 26);
        let fast = round_f32(&a);
        let (x, st) = refined_solve(&DenseOp(&a), &DenseOp(&fast), &[], None, None, 1e-8, 1e-4, 5, 10);
        assert!(x.is_empty());
        assert_eq!(st.outer_iters, 0);
        let b = vec![0.0; 8];
        let (x, st) = refined_solve(&DenseOp(&a), &DenseOp(&fast), &b, None, None, 1e-8, 1e-4, 5, 10);
        assert_eq!(st.outer_iters, 0);
        assert!(st.converged);
        assert!(x.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn empty_and_zero_rhs() {
        let a = random_spd(8, 10);
        let (x, s) = pcg_batch_warm(&DenseOp(&a), &[], None, None, 1e-8, 10);
        assert!(x.is_empty());
        assert_eq!(s.iters, 0);
        let b = vec![0.0; 8];
        let (x, s) = pcg_batch_warm(&DenseOp(&a), &b, None, Some(&IdentityPrecond), 1e-8, 10);
        assert_eq!(s.iters, 0);
        assert_eq!(s.mvm_rows, 0);
        assert!(x.iter().all(|&v| v == 0.0));
        assert_eq!(s.health(), crate::linalg::SolveHealth::Converged);
    }

    #[test]
    fn indefinite_operator_reports_breakdown_not_convergence() {
        // A symmetric indefinite "operator": pᵀAp goes negative along e0,
        // which historically zeroed the residual norm and reported a false
        // convergence. Now it must surface as a Breakdown.
        let n = 6;
        let mut a = Matrix::from_vec(n, n, vec![0.0; n * n]);
        for i in 0..n {
            a[(i, i)] = 1.0;
        }
        a[(0, 0)] = -1.0;
        let mut b = vec![0.0; n];
        b[0] = 1.0;
        let (_, s) = pcg_batch_warm(&DenseOp(&a), &b, None, None, 1e-8, 50);
        assert!(!s.converged, "breakdown must not report convergence: {s:?}");
        assert_eq!(s.breakdowns, 1);
        assert_eq!(s.health(), crate::linalg::SolveHealth::Breakdown);
        // The true residual is reported, not the compaction-zeroed one.
        assert!(s.rel_residual[0] > 1e-8, "rel={:?}", s.rel_residual);
    }

    #[test]
    fn breakdown_freezes_one_rhs_others_converge() {
        // Batch of [bad-direction RHS, healthy RHS] against the same
        // indefinite operator: the healthy RHS (supported away from the
        // negative eigenvector) still converges; only the bad one breaks.
        let n = 6;
        let mut a = Matrix::from_vec(n, n, vec![0.0; n * n]);
        for i in 0..n {
            a[(i, i)] = 1.0 + 0.1 * i as f64;
        }
        a[(0, 0)] = -1.0;
        let mut b = vec![0.0; 2 * n];
        b[0] = 1.0; // lives on the negative eigenvector
        b[n + 3] = 2.0; // lives on a positive one
        let (x, s) = pcg_batch_warm(&DenseOp(&a), &b, None, None, 1e-10, 50);
        assert_eq!(s.breakdowns, 1);
        assert!(!s.converged);
        assert!(s.rel_residual[1] <= 1e-10 * 1.0001, "healthy rhs converged");
        // diag system: x = b/diag for the healthy RHS
        assert!((x[n + 3] - 2.0 / 1.3).abs() < 1e-9);
    }

    #[test]
    fn nan_rhs_reports_non_finite() {
        let a = random_spd(8, 30);
        let mut b = vec![1.0; 8];
        b[2] = f64::NAN;
        let (_, s) = pcg_batch_warm(&DenseOp(&a), &b, None, None, 1e-8, 50);
        assert!(!s.converged);
        assert!(s.non_finite);
        assert_eq!(s.health(), crate::linalg::SolveHealth::NonFinite);
    }

    #[test]
    fn max_iters_health_is_max_iters() {
        let n = 40;
        let a = random_spd(n, 31);
        let mut rng = Pcg64::new(32);
        let b = rng.normal_vec(n);
        let (_, s) = pcg_batch_warm(&DenseOp(&a), &b, None, None, 1e-12, 1);
        assert!(!s.converged);
        assert_eq!(s.breakdowns, 0);
        assert_eq!(s.health(), crate::linalg::SolveHealth::MaxIters);
        let (_, full) = pcg_batch_warm(&DenseOp(&a), &b, None, None, 1e-12, 2000);
        assert_eq!(full.health(), crate::linalg::SolveHealth::Converged);
    }
}
