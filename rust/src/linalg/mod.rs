//! Dense + iterative linear algebra substrate (built from scratch; the
//! offline crate set has no linalg crates).
//!
//! Everything the GP engines need: a dense row-major [`Matrix`], Cholesky
//! factorization ([`cholesky`]), batched conjugate gradients ([`cg`]),
//! batched *preconditioned* CG with active-set compaction ([`pcg`]),
//! rank-r partial pivoted Cholesky ([`pivoted_cholesky`]), Lanczos /
//! stochastic Lanczos quadrature ([`lanczos`]), and a Jacobi symmetric
//! eigensolver ([`eigh`]).

pub mod cg;
pub mod cholesky;
pub mod eigh;
pub mod lanczos;
pub mod matrix;
pub mod pcg;
pub mod pivoted_cholesky;

pub use cg::{cg_batch, cg_batch_warm, CgStats, LinOp};
pub use cholesky::{chol_logdet, chol_sample, chol_solve, cholesky, solve_lower, solve_lower_t};
pub use eigh::{jacobi_eigh, tridiag_eigh};
pub use lanczos::{lanczos, slq_logdet};
pub use matrix::Matrix;
pub use pcg::{pcg_batch_warm, IdentityPrecond, Preconditioner};
pub use pivoted_cholesky::{pivoted_cholesky, pivoted_cholesky_fn, PivotedCholesky};
