//! Dense + iterative linear algebra substrate (built from scratch; the
//! offline crate set has no linalg crates).
//!
//! Everything the GP engines need: a dense row-major [`Matrix`], Cholesky
//! factorization ([`cholesky`]), batched conjugate gradients ([`cg`]),
//! batched *preconditioned* CG with active-set compaction ([`pcg`]),
//! rank-r partial pivoted Cholesky ([`pivoted_cholesky`]), Lanczos /
//! stochastic Lanczos quadrature ([`lanczos`]), and a Jacobi symmetric
//! eigensolver ([`eigh`]).

pub mod cg;
pub mod cholesky;
pub mod eigh;
pub mod lanczos;
pub mod matrix;
pub mod pcg;
pub mod pivoted_cholesky;

pub use cg::{cg_batch, cg_batch_warm, CgStats, LinOp, SolveHealth};
pub use cholesky::{chol_logdet, chol_sample, chol_solve, cholesky, solve_lower, solve_lower_t};
pub use eigh::{jacobi_eigh, tridiag_eigh};
pub use lanczos::{lanczos, slq_logdet};
pub use matrix::{matmul_mixed_a32b, matmul_mixed_ab32, Matrix, MatrixF32};
pub use pcg::{pcg_batch_warm, refined_solve, IdentityPrecond, Preconditioner, RefineStats};
pub use pivoted_cholesky::{pivoted_cholesky, pivoted_cholesky_fn, PivotedCholesky};
