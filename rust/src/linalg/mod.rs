//! Dense + iterative linear algebra substrate (built from scratch; the
//! offline crate set has no linalg crates).
//!
//! Everything the GP engines need: a dense row-major [`Matrix`], Cholesky
//! factorization ([`cholesky`]), batched conjugate gradients ([`cg`]),
//! Lanczos / stochastic Lanczos quadrature ([`lanczos`]), and a Jacobi
//! symmetric eigensolver ([`eigh`]).

pub mod cg;
pub mod cholesky;
pub mod eigh;
pub mod lanczos;
pub mod matrix;

pub use cg::{cg_batch, cg_batch_warm, CgStats, LinOp};
pub use cholesky::{chol_logdet, chol_sample, chol_solve, cholesky, solve_lower, solve_lower_t};
pub use eigh::{jacobi_eigh, tridiag_eigh};
pub use lanczos::{lanczos, slq_logdet};
pub use matrix::Matrix;
