//! Symmetric eigensolver (cyclic Jacobi).
//!
//! Sized for the small matrices the GP stack diagonalizes: Lanczos
//! tridiagonals (k <= ~32) in stochastic Lanczos quadrature, and test
//! oracles. O(k^3) per sweep, converges quadratically; a handful of sweeps
//! suffices at these sizes.

use super::Matrix;

/// Eigen-decomposition of a symmetric matrix by cyclic Jacobi rotations.
///
/// Returns (eigenvalues, eigenvectors as columns). Eigenvalues are NOT
/// sorted (callers that need order sort by value).
pub fn jacobi_eigh(a: &Matrix, max_sweeps: usize) -> (Vec<f64>, Matrix) {
    let n = a.rows();
    assert_eq!(n, a.cols(), "jacobi_eigh needs square");
    let mut a = a.clone();
    let mut v = Matrix::eye(n);

    for _ in 0..max_sweeps {
        let mut off = 0.0;
        for p in 0..n {
            for q in p + 1..n {
                off += a[(p, q)] * a[(p, q)];
            }
        }
        if off.sqrt() < 1e-14 * (1.0 + a.fro_norm()) {
            break;
        }
        for p in 0..n {
            for q in p + 1..n {
                let apq = a[(p, q)];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let (app, aqq) = (a[(p, p)], a[(q, q)]);
                let theta = 0.5 * (2.0 * apq).atan2(aqq - app);
                let (s, c) = theta.sin_cos();
                // A <- G^T A G, G rotates plane (p, q).
                for k in 0..n {
                    let (akp, akq) = (a[(k, p)], a[(k, q)]);
                    a[(k, p)] = c * akp - s * akq;
                    a[(k, q)] = s * akp + c * akq;
                }
                for k in 0..n {
                    let (apk, aqk) = (a[(p, k)], a[(q, k)]);
                    a[(p, k)] = c * apk - s * aqk;
                    a[(q, k)] = s * apk + c * aqk;
                }
                for k in 0..n {
                    let (vkp, vkq) = (v[(k, p)], v[(k, q)]);
                    v[(k, p)] = c * vkp - s * vkq;
                    v[(k, q)] = s * vkp + c * vkq;
                }
            }
        }
    }

    let evals = (0..n).map(|i| a[(i, i)]).collect();
    (evals, v)
}

/// Eigendecomposition of a symmetric tridiagonal given diagonal `alpha` and
/// off-diagonal `beta` (used by SLQ on the Lanczos T matrix).
pub fn tridiag_eigh(alpha: &[f64], beta: &[f64]) -> (Vec<f64>, Matrix) {
    let k = alpha.len();
    debug_assert!(beta.len() + 1 == k || (k == 0 && beta.is_empty()));
    let mut t = Matrix::zeros(k, k);
    for i in 0..k {
        t[(i, i)] = alpha[i];
        if i + 1 < k {
            t[(i, i + 1)] = beta[i];
            t[(i + 1, i)] = beta[i];
        }
    }
    jacobi_eigh(&t, 30)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn diagonal_is_fixed_point() {
        let mut d = Matrix::zeros(4, 4);
        for i in 0..4 {
            d[(i, i)] = (i + 1) as f64;
        }
        let (mut evals, _) = jacobi_eigh(&d, 10);
        evals.sort_by(f64::total_cmp);
        assert_eq!(evals, vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn reconstructs_symmetric_matrix() {
        let mut rng = Pcg64::new(1);
        for n in [2, 5, 12, 24] {
            let raw = Matrix::from_vec(n, n, rng.normal_vec(n * n));
            let mut sym = raw.clone();
            for i in 0..n {
                for j in 0..n {
                    sym[(i, j)] = 0.5 * (raw[(i, j)] + raw[(j, i)]);
                }
            }
            let (evals, v) = jacobi_eigh(&sym, 30);
            // reconstruct V diag(e) V^T
            let mut vd = v.clone();
            for i in 0..n {
                for j in 0..n {
                    vd[(i, j)] *= evals[j];
                }
            }
            let rec = vd.matmul(&v.transpose());
            assert!(rec.max_abs_diff(&sym) < 1e-9, "n={n}");
        }
    }

    #[test]
    fn eigenvectors_orthonormal() {
        let mut rng = Pcg64::new(2);
        let n = 10;
        let raw = Matrix::from_vec(n, n, rng.normal_vec(n * n));
        let sym_src = raw.matmul(&raw.transpose());
        let (_, v) = jacobi_eigh(&sym_src, 30);
        let vtv = v.transpose().matmul(&v);
        assert!(vtv.max_abs_diff(&Matrix::eye(n)) < 1e-10);
    }

    #[test]
    fn tridiag_matches_dense() {
        let alpha = vec![2.0, 3.0, 4.0, 5.0];
        let beta = vec![0.5, 0.25, 0.75];
        let (mut evals, _) = tridiag_eigh(&alpha, &beta);
        evals.sort_by(f64::total_cmp);
        let mut t = Matrix::zeros(4, 4);
        for i in 0..4 {
            t[(i, i)] = alpha[i];
        }
        for i in 0..3 {
            t[(i, i + 1)] = beta[i];
            t[(i + 1, i)] = beta[i];
        }
        let (mut evals2, _) = jacobi_eigh(&t, 30);
        evals2.sort_by(f64::total_cmp);
        for (a, b) in evals.iter().zip(&evals2) {
            assert!((a - b).abs() < 1e-10);
        }
    }

    #[test]
    fn trace_and_logdet_preserved() {
        let mut rng = Pcg64::new(5);
        let n = 8;
        let raw = Matrix::from_vec(n, n, rng.normal_vec(n * n));
        let mut spd = raw.matmul(&raw.transpose());
        spd.add_diag(n as f64);
        let (evals, _) = jacobi_eigh(&spd, 30);
        let trace: f64 = (0..n).map(|i| spd[(i, i)]).sum();
        assert!((evals.iter().sum::<f64>() - trace).abs() < 1e-9);
        let l = super::super::cholesky::cholesky(&spd).unwrap();
        let want = super::super::cholesky::chol_logdet(&l);
        let got: f64 = evals.iter().map(|e| e.ln()).sum();
        assert!((got - want).abs() < 1e-8);
    }
}
