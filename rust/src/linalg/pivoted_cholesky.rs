//! Rank-r partial pivoted Cholesky of a PSD matrix.
//!
//! The low-rank kernel approximation behind the latent-Kronecker CG
//! preconditioner (GPyTorch's machinery, Gardner et al. 2018): greedily
//! factor A ≈ L Lᵀ with L ∈ R^{n×r}, picking at each step the pivot with
//! the largest remaining diagonal (Schur-complement) entry. The residual
//! A − L_r L_rᵀ is itself a Schur complement, hence PSD, so the
//! approximation error is monotone non-increasing in rank and exactly zero
//! at full rank. O(n r²) time, O(n r) space, touches only the rows of A it
//! pivots on (callers with implicit kernels can pass a dense `Matrix`
//! here because K1 is n×n and already materialized by the GP stack).

use super::Matrix;

/// Result of a partial pivoted Cholesky factorization.
#[derive(Clone, Debug)]
pub struct PivotedCholesky {
    /// (n, rank) factor in ORIGINAL row order: A ≈ l · lᵀ.
    pub l: Matrix,
    /// Pivot indices in selection order (length = rank).
    pub pivots: Vec<usize>,
    /// Trace of the PSD residual A − L Lᵀ at exit (0 at full rank).
    pub trace_residual: f64,
}

impl PivotedCholesky {
    /// Rank actually reached (may be below the requested cap when the
    /// residual trace fell under tolerance first).
    pub fn rank(&self) -> usize {
        self.l.cols()
    }
}

/// Greedy diagonal-pivoted partial Cholesky of a PSD matrix.
///
/// Stops at `max_rank` columns or when the residual trace drops below
/// `rel_tol * trace(A)`, whichever comes first. A non-PSD input (negative
/// residual diagonal beyond roundoff) stops early rather than producing
/// NaNs; the factor built so far is still a valid PSD approximation.
pub fn pivoted_cholesky(a: &Matrix, max_rank: usize, rel_tol: f64) -> PivotedCholesky {
    let n = a.rows();
    assert_eq!(n, a.cols(), "pivoted_cholesky needs square");
    let diag: Vec<f64> = (0..n).map(|i| a[(i, i)]).collect();
    pivoted_cholesky_fn(
        &diag,
        &mut |piv, out| {
            for (i, o) in out.iter_mut().enumerate() {
                *o = a[(i, piv)];
            }
        },
        max_rank,
        rel_tol,
    )
}

/// [`pivoted_cholesky`] against an *implicit* matrix: `diag` is the full
/// diagonal, `column(piv, out)` fills column `piv`. Only `rank` columns
/// are ever requested, so an n_obs × n_obs observed-covariance Gram is
/// factored in O(n·r) entry evaluations without materializing it — the
/// GPyTorch-style preconditioner path relies on this.
pub fn pivoted_cholesky_fn(
    diag: &[f64],
    column: &mut dyn FnMut(usize, &mut [f64]),
    max_rank: usize,
    rel_tol: f64,
) -> PivotedCholesky {
    let n = diag.len();
    let max_rank = max_rank.min(n);

    // Remaining Schur-complement diagonal.
    let mut d: Vec<f64> = diag.to_vec();
    let trace0: f64 = d.iter().sum();
    let stop = rel_tol * trace0.max(0.0);

    // Columns are built in selection order, then packed into (n, rank).
    let mut cols: Vec<Vec<f64>> = Vec::with_capacity(max_rank);
    let mut pivots: Vec<usize> = Vec::with_capacity(max_rank);

    for _ in 0..max_rank {
        // Largest remaining diagonal entry (pivoted rows were zeroed, so
        // they can never win the scan again).
        let mut piv = usize::MAX;
        let mut best = 0.0;
        for (i, &di) in d.iter().enumerate() {
            if di > best {
                best = di;
                piv = i;
            }
        }
        if piv == usize::MAX || best <= 1e-300 {
            break;
        }
        let root = best.sqrt();
        // col = (A[:, piv] - sum_j l[:,j] l[piv,j]) / root
        let mut col = vec![0.0; n];
        column(piv, &mut col);
        for c in cols.iter() {
            let cp = c[piv];
            for (ci, ca) in col.iter_mut().zip(c.iter()) {
                *ci -= ca * cp;
            }
        }
        for ci in col.iter_mut() {
            *ci /= root;
        }
        // Update the residual diagonal; clamp roundoff negatives to zero.
        for (di, ci) in d.iter_mut().zip(&col) {
            *di = (*di - ci * ci).max(0.0);
        }
        d[piv] = 0.0;
        pivots.push(piv);
        cols.push(col);
        let remaining: f64 = d.iter().sum();
        if remaining <= stop {
            break;
        }
    }

    let rank = cols.len();
    let mut l = Matrix::zeros(n, rank);
    for (j, c) in cols.iter().enumerate() {
        for i in 0..n {
            l[(i, j)] = c[i];
        }
    }
    PivotedCholesky {
        l,
        pivots,
        trace_residual: d.iter().sum(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn random_psd(n: usize, seed: u64, jitter: f64) -> Matrix {
        let mut rng = Pcg64::new(seed);
        let a = Matrix::from_vec(n, n, rng.normal_vec(n * n));
        let mut psd = a.matmul(&a.transpose());
        psd.add_diag(jitter);
        psd
    }

    fn approx_error(a: &Matrix, pc: &PivotedCholesky) -> f64 {
        let rec = pc.l.matmul(&pc.l.transpose());
        a.max_abs_diff(&rec)
    }

    #[test]
    fn error_monotone_in_rank_and_exact_at_full() {
        let n = 18;
        let a = random_psd(n, 1, 0.5);
        let mut prev = f64::INFINITY;
        for r in [1, 2, 4, 8, 12, n] {
            let pc = pivoted_cholesky(&a, r, 0.0);
            let err = approx_error(&a, &pc);
            assert!(
                err <= prev + 1e-9,
                "rank {r}: error {err} grew past {prev}"
            );
            prev = err;
        }
        let full = pivoted_cholesky(&a, n, 0.0);
        assert!(approx_error(&a, &full) < 1e-8, "full rank not exact");
        assert!(full.trace_residual < 1e-8);
    }

    #[test]
    fn trace_residual_monotone() {
        let a = random_psd(14, 2, 0.1);
        let mut prev = f64::INFINITY;
        for r in 1..=14 {
            let pc = pivoted_cholesky(&a, r, 0.0);
            assert!(pc.trace_residual <= prev + 1e-10, "rank {r}");
            assert!(pc.trace_residual >= -1e-10);
            prev = pc.trace_residual;
        }
    }

    #[test]
    fn low_rank_matrix_recovered_at_its_rank() {
        // A = B Bᵀ with B (n, 3) has exact rank 3.
        let n = 20;
        let mut rng = Pcg64::new(3);
        let b = Matrix::from_vec(n, 3, rng.normal_vec(n * 3));
        let a = b.matmul(&b.transpose());
        let pc = pivoted_cholesky(&a, 10, 1e-12);
        assert!(pc.rank() <= 4, "rank {} for a rank-3 matrix", pc.rank());
        assert!(approx_error(&a, &pc) < 1e-8);
    }

    #[test]
    fn smooth_kernel_compresses_fast() {
        // Long-lengthscale RBF Gram matrices are numerically low rank; a
        // small rank budget must capture nearly all the trace.
        let n = 40;
        let mut rng = Pcg64::new(4);
        let x: Vec<f64> = (0..n).map(|_| rng.uniform()).collect();
        let a = Matrix::from_fn(n, n, |i, j| {
            let d = (x[i] - x[j]) / 2.0;
            (-0.5 * d * d).exp()
        });
        let pc = pivoted_cholesky(&a, 8, 0.0);
        let trace0: f64 = (0..n).map(|i| a[(i, i)]).sum();
        assert!(
            pc.trace_residual < 1e-6 * trace0,
            "residual {} of trace {trace0}",
            pc.trace_residual
        );
    }

    #[test]
    fn psd_approximation_from_below() {
        // The residual A − L Lᵀ is PSD: quadratic forms stay nonnegative.
        let n = 12;
        let a = random_psd(n, 5, 0.2);
        let pc = pivoted_cholesky(&a, 5, 0.0);
        let rec = pc.l.matmul(&pc.l.transpose());
        let mut rng = Pcg64::new(6);
        for _ in 0..20 {
            let v = rng.normal_vec(n);
            let av = a.matvec(&v);
            let rv = rec.matvec(&v);
            let quad: f64 = (0..n).map(|i| v[i] * (av[i] - rv[i])).sum();
            assert!(quad > -1e-8, "residual not PSD: {quad}");
        }
    }

    #[test]
    fn implicit_column_oracle_matches_dense() {
        let a = random_psd(16, 7, 0.3);
        let dense = pivoted_cholesky(&a, 6, 0.0);
        let diag: Vec<f64> = (0..16).map(|i| a[(i, i)]).collect();
        let implicit = pivoted_cholesky_fn(
            &diag,
            &mut |piv, out| {
                for (i, o) in out.iter_mut().enumerate() {
                    *o = a[(i, piv)];
                }
            },
            6,
            0.0,
        );
        assert_eq!(dense.pivots, implicit.pivots);
        assert_eq!(dense.l, implicit.l);
    }

    #[test]
    fn zero_and_identity_edge_cases() {
        let z = Matrix::zeros(5, 5);
        let pc = pivoted_cholesky(&z, 5, 0.0);
        assert_eq!(pc.rank(), 0);
        assert_eq!(pc.trace_residual, 0.0);

        let e = Matrix::eye(6);
        let pc = pivoted_cholesky(&e, 6, 0.0);
        assert_eq!(pc.rank(), 6);
        assert!(approx_error(&e, &pc) < 1e-12);
    }
}
