//! Dense Cholesky factorization and triangular solves.
//!
//! Used by (a) the naive O(n^3 m^3) joint-covariance engine that is the
//! paper's Figure-3 baseline, and (b) the Kronecker-factor Cholesky in
//! Matheron prior sampling (O(n^3 + m^3), paper §2).

use super::Matrix;
use crate::error::{LkgpError, Result};

/// Lower-triangular Cholesky factor of an SPD matrix.
///
/// Right-looking, row-major friendly. Returns an error (not NaNs) when the
/// matrix is not positive definite, which the trainers treat as a rejected
/// step.
pub fn cholesky(a: &Matrix) -> Result<Matrix> {
    let n = a.rows();
    if n != a.cols() {
        return Err(LkgpError::Shape(format!(
            "cholesky needs square, got {}x{}",
            n,
            a.cols()
        )));
    }
    let mut l = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            // dot over the first j entries of rows i and j.
            let s = super::matrix::dot(&l.row(i)[..j], &l.row(j)[..j]);
            if i == j {
                let d = a[(i, i)] - s;
                if d <= 0.0 || !d.is_finite() {
                    return Err(LkgpError::NotPd { index: i, value: d });
                }
                l[(i, j)] = d.sqrt();
            } else {
                l[(i, j)] = (a[(i, j)] - s) / l[(j, j)];
            }
        }
    }
    Ok(l)
}

/// Solve L x = b (forward substitution), L lower-triangular.
pub fn solve_lower(l: &Matrix, b: &[f64]) -> Vec<f64> {
    let n = l.rows();
    debug_assert_eq!(b.len(), n);
    let mut x = b.to_vec();
    for i in 0..n {
        let s = super::matrix::dot(&l.row(i)[..i], &x[..i]);
        x[i] = (x[i] - s) / l[(i, i)];
    }
    x
}

/// Solve L^T x = b (backward substitution), L lower-triangular.
pub fn solve_lower_t(l: &Matrix, b: &[f64]) -> Vec<f64> {
    let n = l.rows();
    debug_assert_eq!(b.len(), n);
    let mut x = b.to_vec();
    for i in (0..n).rev() {
        let mut s = 0.0;
        for k in i + 1..n {
            s += l[(k, i)] * x[k];
        }
        x[i] = (x[i] - s) / l[(i, i)];
    }
    x
}

/// Solve A x = b given the Cholesky factor L (A = L L^T).
pub fn chol_solve(l: &Matrix, b: &[f64]) -> Vec<f64> {
    solve_lower_t(l, &solve_lower(l, b))
}

/// log det A from its Cholesky factor.
pub fn chol_logdet(l: &Matrix) -> f64 {
    let n = l.rows();
    let mut s = 0.0;
    for i in 0..n {
        s += l[(i, i)].ln();
    }
    2.0 * s
}

/// Sample from N(0, A) given L: returns L z for z ~ N(0, I).
pub fn chol_sample(l: &Matrix, z: &[f64]) -> Vec<f64> {
    let n = l.rows();
    debug_assert_eq!(z.len(), n);
    let mut out = vec![0.0; n];
    for i in 0..n {
        out[i] = super::matrix::dot(&l.row(i)[..=i], &z[..=i]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn random_spd(n: usize, seed: u64) -> Matrix {
        let mut rng = Pcg64::new(seed);
        let a = Matrix::from_vec(n, n, rng.normal_vec(n * n));
        let mut spd = a.matmul(&a.transpose());
        spd.add_diag(n as f64);
        spd
    }

    #[test]
    fn factor_reconstructs() {
        for n in [1, 2, 5, 20, 50] {
            let a = random_spd(n, n as u64);
            let l = cholesky(&a).unwrap();
            let rec = l.matmul(&l.transpose());
            assert!(rec.max_abs_diff(&a) < 1e-9, "n={n}");
        }
    }

    #[test]
    fn solve_matches_direct() {
        let n = 30;
        let a = random_spd(n, 7);
        let l = cholesky(&a).unwrap();
        let mut rng = Pcg64::new(8);
        let b = rng.normal_vec(n);
        let x = chol_solve(&l, &b);
        let back = a.matvec(&x);
        for i in 0..n {
            assert!((back[i] - b[i]).abs() < 1e-8);
        }
    }

    #[test]
    fn logdet_matches_product() {
        let a = random_spd(12, 3);
        let l = cholesky(&a).unwrap();
        // compare against sum of log eigenvalues via jacobi
        let (evals, _) = super::super::eigh::jacobi_eigh(&a, 40);
        let want: f64 = evals.iter().map(|e| e.ln()).sum();
        assert!((chol_logdet(&l) - want).abs() < 1e-8);
    }

    #[test]
    fn rejects_non_pd() {
        let mut a = Matrix::eye(3);
        a[(2, 2)] = -1.0;
        assert!(cholesky(&a).is_err());
    }

    #[test]
    fn rejects_non_square() {
        let a = Matrix::zeros(3, 4);
        assert!(cholesky(&a).is_err());
    }

    #[test]
    fn triangular_solves_consistent() {
        let n = 15;
        let a = random_spd(n, 11);
        let l = cholesky(&a).unwrap();
        let mut rng = Pcg64::new(12);
        let b = rng.normal_vec(n);
        let y = solve_lower(&l, &b);
        let back = l.matvec(&y);
        for i in 0..n {
            assert!((back[i] - b[i]).abs() < 1e-9);
        }
        let x = solve_lower_t(&l, &b);
        let back_t = l.transpose().matvec(&x);
        for i in 0..n {
            assert!((back_t[i] - b[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn sample_covariance_converges() {
        let n = 4;
        let a = random_spd(n, 21);
        let l = cholesky(&a).unwrap();
        let mut rng = Pcg64::new(22);
        let s = 30000;
        let mut cov = Matrix::zeros(n, n);
        for _ in 0..s {
            let x = chol_sample(&l, &rng.normal_vec(n));
            for i in 0..n {
                for j in 0..n {
                    cov[(i, j)] += x[i] * x[j] / s as f64;
                }
            }
        }
        let scale = a.fro_norm();
        assert!(cov.max_abs_diff(&a) / scale < 0.05);
    }
}
