//! The data plane: many-task corpora behind one [`Corpus`] trait.
//!
//! The serving stack historically had two ad-hoc data entry points — the
//! built-in simulator and a dangling `Task::load_json` nobody above it
//! consumed. This module unifies them: a [`Corpus`] is an ordered set of
//! learning-curve tasks with per-task metadata, lazy task materialization,
//! streaming iteration with **per-task error isolation** (one corrupt file
//! must not kill a 1000-task run), and a stable [`Corpus::fingerprint`]
//! that request traces pin so a replay can refuse to run against the wrong
//! data (docs/data.md).
//!
//! Three implementations:
//!
//! * [`SimCorpus`] — the deterministic simulator as a corpus. Task `t` is
//!   `Task::generate(presets[t % 3], configs, Pcg64::new(seed + t))`,
//!   bit-identical to the historical inline generation in `lkgp pool` and
//!   the trace replayer, so every simulator-driven path keeps its exact
//!   behavior through the adapter.
//! * [`JsonDirCorpus`] — a directory of LCBench-style JSON dumps, one task
//!   per `*.json` file (sorted by file name), parsed lazily through the
//!   hardened [`Task::load_json`] and cached. A file that fails
//!   validation yields an error for *that* task only.
//! * [`TraceCorpus`] — the corpus a trace header pins (sim parameters or
//!   a directory path + fingerprint), resolved back into one of the above.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use crate::coordinator::{CurveStore, Registry, Snapshot, TrialId};
use crate::rng::Pcg64;
use crate::util::lock_clean;

use super::{Preset, Task};

/// Per-task metadata a corpus can report without (for sim) or after (for
/// JSON) materializing the task.
#[derive(Clone, Debug)]
pub struct TaskMeta {
    /// Index of the task within the corpus.
    pub id: usize,
    /// Human-readable task name (preset name or file stem).
    pub name: String,
    /// Number of hyper-parameter configurations.
    pub n: usize,
    /// Grid length (epochs).
    pub m: usize,
    /// Hyper-parameter dimensionality.
    pub d: usize,
    /// Observed fraction of the (n, m) curve grid — 1.0 when no config is
    /// early-stopped.
    pub mask_density: f64,
}

/// An ordered collection of learning-curve tasks: the single data-plane
/// abstraction every consumer (pool admission, trace record/replay, CLI,
/// benches) is written against.
pub trait Corpus: Send + Sync {
    /// Number of tasks in the corpus.
    fn len(&self) -> usize;

    /// Whether the corpus holds no tasks.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Short corpus name for logs and reports.
    fn name(&self) -> String;

    /// Stable content fingerprint. Traces record it; replays verify it.
    fn fingerprint(&self) -> String;

    /// Header fields a trace records to pin this corpus: the `"corpus"`
    /// kind (`"sim"` or `"dir"`) plus whatever reconstructs it
    /// (`coordinator::trace` resolves the pin back through
    /// [`TraceCorpus`]).
    fn trace_pin(&self) -> Vec<(String, crate::json::Json)>;

    /// Materialize (and cache) one task. Errors are per-task: a corrupt
    /// task leaves every other id servable.
    fn task(&self, id: usize) -> crate::Result<Arc<Task>>;

    /// Metadata for one task (materializes it for JSON corpora).
    fn meta(&self, id: usize) -> crate::Result<TaskMeta> {
        let task = self.task(id)?;
        Ok(TaskMeta {
            id,
            name: task.name.clone(),
            n: task.n(),
            m: task.m(),
            d: task.configs.cols(),
            mask_density: task.mask_density(),
        })
    }

    /// Streaming iteration over `(id, task-or-error)` pairs — the
    /// error-isolated ingestion loop (`for (id, t) in corpus.tasks()`).
    fn tasks(&self) -> CorpusIter<'_>
    where
        Self: Sized,
    {
        CorpusIter { corpus: self, next: 0 }
    }
}

/// Iterator returned by [`Corpus::tasks`]: yields every task id with its
/// materialization result, isolating per-task failures.
pub struct CorpusIter<'a> {
    corpus: &'a dyn Corpus,
    next: usize,
}

impl Iterator for CorpusIter<'_> {
    type Item = (usize, crate::Result<Arc<Task>>);

    fn next(&mut self) -> Option<Self::Item> {
        if self.next >= self.corpus.len() {
            return None;
        }
        let id = self.next;
        self.next += 1;
        Some((id, self.corpus.task(id)))
    }
}

// ---------------------------------------------------------------------------
// Fingerprinting

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

fn fnv1a(bytes: &[u8], mut h: u64) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

// ---------------------------------------------------------------------------
// SimCorpus

/// The deterministic workload simulator as a corpus (see module docs for
/// the exact generation recipe — it matches the historical inline paths
/// bit for bit).
pub struct SimCorpus {
    tasks: usize,
    configs: usize,
    seed: u64,
    cache: Mutex<HashMap<usize, Arc<Task>>>,
}

impl SimCorpus {
    pub fn new(tasks: usize, configs: usize, seed: u64) -> Self {
        SimCorpus {
            tasks: tasks.max(1),
            configs: configs.max(2),
            seed,
            cache: Mutex::new(HashMap::new()),
        }
    }

    /// Configs per task (uniform for simulated corpora).
    pub fn configs(&self) -> usize {
        self.configs
    }

    /// Base RNG seed (task `t` derives `seed + t`).
    pub fn seed(&self) -> u64 {
        self.seed
    }
}

impl Corpus for SimCorpus {
    fn len(&self) -> usize {
        self.tasks
    }

    fn name(&self) -> String {
        "sim".into()
    }

    fn fingerprint(&self) -> String {
        // parameters fully determine the content, so they ARE the print
        format!("sim-t{}-c{}-s{}", self.tasks, self.configs, self.seed)
    }

    fn trace_pin(&self) -> Vec<(String, crate::json::Json)> {
        use crate::json::Json;
        vec![
            ("corpus".into(), Json::Str("sim".into())),
            ("configs".into(), Json::Num(self.configs as f64)),
            ("seed".into(), Json::Num(self.seed as f64)),
        ]
    }

    fn task(&self, id: usize) -> crate::Result<Arc<Task>> {
        if id >= self.tasks {
            return Err(crate::LkgpError::Coordinator(format!(
                "sim corpus has {} tasks, no task {id}",
                self.tasks
            )));
        }
        if let Some(t) = lock_clean(&self.cache).get(&id) {
            return Ok(t.clone());
        }
        let presets = Preset::all();
        let mut rng = Pcg64::new(self.seed + id as u64);
        let task = Arc::new(Task::generate(
            presets[id % presets.len()],
            self.configs,
            &mut rng,
        ));
        lock_clean(&self.cache).insert(id, task.clone());
        Ok(task)
    }
}

// ---------------------------------------------------------------------------
// JsonDirCorpus

/// A directory of LCBench-style JSON dumps: one task per `*.json` file,
/// ordered by file name, parsed lazily through [`Task::load_json`].
pub struct JsonDirCorpus {
    dir: PathBuf,
    /// (stem, path) per task, sorted by file name for a stable order.
    files: Vec<(String, PathBuf)>,
    cache: Mutex<HashMap<usize, Arc<Task>>>,
    /// Per-file content digests keyed by path and validated by
    /// `(mtime, len)`. Repeated fingerprint calls — pool admission,
    /// reports, trace headers, every record/replay handshake — cost one
    /// metadata stat per file instead of re-reading the whole corpus, and
    /// a file appended or rewritten between calls (streaming ingestion)
    /// re-reads only itself.
    digests: Mutex<HashMap<PathBuf, FileDigest>>,
}

/// One cached per-file digest with the metadata that validates it.
struct FileDigest {
    mtime: Option<std::time::SystemTime>,
    len: u64,
    digest: u64,
}

impl JsonDirCorpus {
    /// Scan `dir` for `*.json` task files. Fails only when the directory
    /// itself is unreadable or holds no task files — individual files are
    /// validated lazily, per task.
    pub fn open(dir: impl AsRef<Path>) -> crate::Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let mut files = Vec::new();
        for entry in std::fs::read_dir(&dir)? {
            let path = entry?.path();
            if path.extension().and_then(|e| e.to_str()) != Some("json") {
                continue;
            }
            let stem = path
                .file_stem()
                .and_then(|s| s.to_str())
                .unwrap_or("task")
                .to_string();
            files.push((stem, path));
        }
        files.sort_by(|a, b| a.1.file_name().cmp(&b.1.file_name()));
        if files.is_empty() {
            return Err(crate::LkgpError::Coordinator(format!(
                "corpus dir {} holds no *.json task files",
                dir.display()
            )));
        }
        Ok(JsonDirCorpus {
            dir,
            files,
            cache: Mutex::new(HashMap::new()),
            digests: Mutex::new(HashMap::new()),
        })
    }

    /// The directory this corpus was opened on.
    pub fn dir(&self) -> &Path {
        &self.dir
    }
}

impl Corpus for JsonDirCorpus {
    fn len(&self) -> usize {
        self.files.len()
    }

    fn name(&self) -> String {
        self.dir.display().to_string()
    }

    fn fingerprint(&self) -> String {
        // FNV-1a over per-file digests in task order: any rename, reorder,
        // or byte change re-prints. Each file's digest (seeded by its stem,
        // run over its content) is cached keyed by `(mtime, len)`, so only
        // changed files are re-read on later calls — the print always
        // reflects current content, unlike the old once-forever memo.
        // Unreadable files hash an error marker (uncached, so recovery is
        // noticed) to keep the print stable and total.
        let mut cache = lock_clean(&self.digests);
        let mut h = FNV_OFFSET;
        for (stem, path) in &self.files {
            let seed = fnv1a(stem.as_bytes(), FNV_OFFSET);
            let digest = match std::fs::metadata(path) {
                Ok(meta) => {
                    let (mtime, len) = (meta.modified().ok(), meta.len());
                    let hit = cache
                        .get(path)
                        .filter(|e| e.mtime == mtime && mtime.is_some() && e.len == len)
                        .map(|e| e.digest);
                    match hit {
                        Some(d) => d,
                        None => match std::fs::read(path) {
                            Ok(bytes) => {
                                let d = fnv1a(&bytes, seed);
                                cache.insert(path.clone(), FileDigest { mtime, len, digest: d });
                                d
                            }
                            Err(_) => fnv1a(b"<unreadable>", seed),
                        },
                    }
                }
                Err(_) => fnv1a(b"<unreadable>", seed),
            };
            h = fnv1a(&digest.to_le_bytes(), h);
        }
        format!("dir-{h:016x}")
    }

    fn trace_pin(&self) -> Vec<(String, crate::json::Json)> {
        use crate::json::Json;
        vec![
            ("corpus".into(), Json::Str("dir".into())),
            ("path".into(), Json::Str(self.dir.display().to_string())),
        ]
    }

    fn task(&self, id: usize) -> crate::Result<Arc<Task>> {
        let Some((stem, path)) = self.files.get(id) else {
            return Err(crate::LkgpError::Coordinator(format!(
                "corpus {} has {} tasks, no task {id}",
                self.dir.display(),
                self.files.len()
            )));
        };
        if let Some(t) = lock_clean(&self.cache).get(&id) {
            return Ok(t.clone());
        }
        let text = std::fs::read_to_string(path)?;
        let task = Arc::new(Task::load_json(stem, &text)?);
        lock_clean(&self.cache).insert(id, task.clone());
        Ok(task)
    }
}

// ---------------------------------------------------------------------------
// TraceCorpus

/// The corpus pinned by a recorded trace header: simulator parameters or
/// a dump-directory path, plus the fingerprint the replay verifies.
pub enum TraceCorpus {
    Sim(SimCorpus),
    Dir(JsonDirCorpus),
}

impl TraceCorpus {
    /// Resolve a sim-corpus pin.
    pub fn sim(tasks: usize, configs: usize, seed: u64) -> Self {
        TraceCorpus::Sim(SimCorpus::new(tasks, configs, seed))
    }

    /// Resolve a directory pin (path as recorded, relative to the
    /// replayer's working directory) and verify the fingerprint when the
    /// trace carries one — replaying against drifted data is an error,
    /// not a silent wrong-answer run.
    pub fn dir(path: &str, fingerprint: Option<&str>) -> crate::Result<Self> {
        let corpus = JsonDirCorpus::open(path)?;
        if let Some(want) = fingerprint {
            let got = corpus.fingerprint();
            if got != want {
                return Err(crate::LkgpError::Coordinator(format!(
                    "corpus {path} fingerprint {got} does not match the trace's {want}"
                )));
            }
        }
        Ok(TraceCorpus::Dir(corpus))
    }

    fn inner(&self) -> &dyn Corpus {
        match self {
            TraceCorpus::Sim(c) => c,
            TraceCorpus::Dir(c) => c,
        }
    }
}

impl Corpus for TraceCorpus {
    fn len(&self) -> usize {
        self.inner().len()
    }

    fn name(&self) -> String {
        self.inner().name()
    }

    fn fingerprint(&self) -> String {
        self.inner().fingerprint()
    }

    fn trace_pin(&self) -> Vec<(String, crate::json::Json)> {
        self.inner().trace_pin()
    }

    fn task(&self, id: usize) -> crate::Result<Arc<Task>> {
        self.inner().task(id)
    }

    fn meta(&self, id: usize) -> crate::Result<TaskMeta> {
        self.inner().meta(id)
    }
}

// ---------------------------------------------------------------------------
// Snapshot reconstruction

/// Build the deterministic generation ladder the v1 trace format pins:
/// generation `g + 1` observes `gen_epochs[g]` epochs on config 0, with
/// per-config stagger `i % 3` for realistic prefix masks. Extracted
/// verbatim from the original replay harness so v1 traces reconstruct
/// bit-identical snapshots; observation values clamp to the task's
/// observed prefix so early-stopped (ragged) corpus tasks replay too.
pub fn progressive_snapshots(
    task: &Task,
    gen_epochs: &[usize],
    max_epochs: usize,
) -> crate::Result<Vec<Snapshot>> {
    let mut reg = Registry::new();
    let ids: Vec<TrialId> = (0..task.n())
        .map(|i| reg.add(task.configs.row(i).to_vec()))
        .collect();
    let mut store = CurveStore::new(max_epochs);
    let mut observed = vec![0usize; task.n()];
    let mut snaps = Vec::with_capacity(gen_epochs.len());
    for &budget in gen_epochs {
        for (i, &id) in ids.iter().enumerate() {
            let upto = budget.saturating_sub(i % 3).max(1).min(max_epochs);
            while observed[i] < upto {
                let j = observed[i].min(task.lengths[i].saturating_sub(1)).min(task.m() - 1);
                reg.observe(id, task.curves[(i, j)], max_epochs)?;
                observed[i] += 1;
            }
        }
        snaps.push(store.snapshot(&reg)?);
    }
    Ok(snaps)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_corpus_matches_inline_generation_bit_for_bit() {
        let corpus = SimCorpus::new(4, 10, 17);
        for t in 0..4 {
            let presets = Preset::all();
            let mut rng = Pcg64::new(17 + t as u64);
            let want = Task::generate(presets[t % presets.len()], 10, &mut rng);
            let got = corpus.task(t).unwrap();
            assert_eq!(got.curves.data(), want.curves.data(), "task {t}");
            assert_eq!(got.configs.data(), want.configs.data(), "task {t}");
        }
        // cached second read is the same Arc
        let a = corpus.task(0).unwrap();
        let b = corpus.task(0).unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        assert!(corpus.task(4).is_err());
    }

    #[test]
    fn sim_meta_and_fingerprint() {
        let corpus = SimCorpus::new(2, 8, 5);
        let meta = corpus.meta(1).unwrap();
        assert_eq!((meta.n, meta.m, meta.d), (8, super::super::EPOCHS, super::super::DIMS));
        assert_eq!(meta.mask_density, 1.0);
        assert_eq!(corpus.fingerprint(), "sim-t2-c8-s5");
        assert_ne!(corpus.fingerprint(), SimCorpus::new(2, 8, 6).fingerprint());
    }

    #[test]
    fn progressive_snapshots_build_the_v1_ladder() {
        let corpus = SimCorpus::new(1, 8, 17);
        let task = corpus.task(0).unwrap();
        let snaps = progressive_snapshots(&task, &[4, 7, 10], 12).unwrap();
        assert_eq!(snaps.len(), 3);
        for (g, s) in snaps.iter().enumerate() {
            assert_eq!(s.generation, g as u64 + 1);
            assert_eq!(s.data.n(), 8);
            assert_eq!(s.data.m(), 12);
        }
        // config 0 observes exactly the budget; config 1 staggers by 1
        let m0: usize = (0..12).filter(|&j| snaps[0].data.mask[(0, j)] > 0.0).count();
        let m1: usize = (0..12).filter(|&j| snaps[0].data.mask[(1, j)] > 0.0).count();
        assert_eq!(m0, 4);
        assert_eq!(m1, 3);
    }
}
