//! LCBench-like learning-curve workload simulator (+ loader for real dumps).
//!
//! The paper's quality experiment (Figure 4) uses LCBench [Zimmer et al.,
//! 2021]: for each task, 2000 MLP configurations over a d = 7 hyper-
//! parameter space, each trained for 52 epochs, recording validation
//! accuracy per epoch. The real dump is not available offline, so this
//! module generates synthetic tasks with the same interface and the curve
//! families LCBench exhibits (DESIGN.md §Substitutions):
//!
//! * saturating power-law growth `acc(t) = a_inf - (a_inf - a_0)(1+t/tau)^-beta`
//! * hyper-parameter-dependent asymptote / speed / start (so curves are
//!   correlated across configs — exactly what LKGP exploits and per-curve
//!   baselines cannot)
//! * heteroskedastic observation noise, occasional spikes, and a
//!   divergence regime for extreme learning rates (Figure 1 right)
//!
//! If a real LCBench JSON dump is available, [`Task::load_json`] accepts
//! `{"configs": [[f64; d]], "curves": [[f64; m]]}` — with ragged
//! (early-stopped) curve rows and optional unique `"ids"` — and
//! everything downstream is identical. The [`corpus`] module scales this
//! from one file to a many-task data plane (simulated, JSON-directory,
//! and trace-pinned corpora behind one `Corpus` trait).

pub mod corpus;

use crate::gp::lkgp::Dataset;
use crate::gp::transforms::{TTransform, XTransform, YTransform};
use crate::linalg::Matrix;
use crate::rng::Pcg64;

/// Number of epochs in LCBench curves.
pub const EPOCHS: usize = 52;
/// Hyper-parameter dimensions (LCBench: batch size, lr, momentum, weight
/// decay, #layers, #units, dropout).
pub const DIMS: usize = 7;

/// Task presets mimicking the three LCBench tasks in the paper's Figure 4.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Preset {
    /// High-accuracy image task (Fashion-MNIST-like): fast saturation.
    FashionMnist,
    /// Tabular task with modest accuracy ceiling (airlines-like).
    Airlines,
    /// Mid-accuracy, slower curves, noisier (higgs-like).
    Higgs,
}

impl Preset {
    pub fn name(self) -> &'static str {
        match self {
            Preset::FashionMnist => "fashion_mnist",
            Preset::Airlines => "airlines",
            Preset::Higgs => "higgs",
        }
    }

    pub fn all() -> [Preset; 3] {
        [Preset::FashionMnist, Preset::Airlines, Preset::Higgs]
    }

    /// (base accuracy floor, asymptote center, asymptote spread, noise)
    fn params(self) -> (f64, f64, f64, f64) {
        match self {
            Preset::FashionMnist => (0.10, 0.89, 0.06, 0.004),
            Preset::Airlines => (0.50, 0.63, 0.04, 0.006),
            Preset::Higgs => (0.45, 0.71, 0.05, 0.009),
        }
    }
}

/// A learning-curve prediction task: configs + ground-truth curves.
///
/// Real dumps are ragged — early-stopped configs record fewer epochs than
/// the grid — so `lengths[i]` is the observed prefix of curve `i`
/// (`curves` entries past it are padding zeros). Simulated tasks are
/// always full (`lengths[i] == m`).
#[derive(Clone, Debug)]
pub struct Task {
    pub name: String,
    /// (n, d) raw hyper-parameter configurations.
    pub configs: Matrix,
    /// (n, m) learning curves (ground truth); entries past `lengths[i]`
    /// are unobserved padding.
    pub curves: Matrix,
    /// Raw epoch grid 1..=m.
    pub epochs: Vec<f64>,
    /// Observed prefix length per config (>= 1, <= m).
    pub lengths: Vec<usize>,
}

impl Task {
    /// Generate a synthetic task with `n` configurations.
    pub fn generate(preset: Preset, n: usize, rng: &mut Pcg64) -> Task {
        let (floor, a_center, a_spread, noise) = preset.params();
        let d = DIMS;
        let mut configs = Matrix::zeros(n, d);
        let mut curves = Matrix::zeros(n, EPOCHS);
        for i in 0..n {
            // raw hyper-parameters in plausible LCBench ranges
            let log_lr = rng.uniform_in(-4.0, -1.0); // log10 lr
            let batch = rng.uniform_in(4.0, 9.0); // log2 batch
            let momentum = rng.uniform_in(0.1, 0.99);
            let weight_decay = rng.uniform_in(-5.0, -2.0); // log10
            let layers = rng.uniform_in(1.0, 5.0);
            let units = rng.uniform_in(4.0, 10.0); // log2
            let dropout = rng.uniform_in(0.0, 0.8);
            let row = [log_lr, batch, momentum, weight_decay, layers, units, dropout];
            configs.row_mut(i).copy_from_slice(&row);

            // hyper-parameter -> curve shape (smooth, correlated)
            let lr_quality = 1.0 - ((log_lr + 2.5) / 1.5).powi(2); // peak at 1e-2.5
            let cap_quality = 0.5 * ((units - 7.0) / 3.0).tanh()
                + 0.3 * ((layers - 3.0) / 2.0).tanh()
                - 0.4 * (dropout - 0.4).powi(2);
            let reg_quality = -0.2 * ((weight_decay + 3.5) / 1.5).powi(2);
            let quality =
                (0.6 * lr_quality + 0.3 * cap_quality + 0.1 * reg_quality).clamp(-2.0, 1.0);
            let a_inf = (a_center + a_spread * quality).min(0.999);
            let a_0 = floor + 0.05 * rng.uniform();
            // speed: higher lr + higher momentum converge faster
            let tau = (8.0 * (1.0 - momentum * 0.5) * (10f64).powf(-(log_lr + 4.0) / 3.0) + 1.0)
                .clamp(0.8, 30.0);
            let beta = rng.uniform_in(0.7, 1.6);
            // divergence regime: very high lr degrades mid-training
            // (gradual, as in LCBench — not a cliff to zero)
            let diverges = log_lr > -1.35 && rng.uniform() < 0.4;
            let diverge_at = 5.0 + 30.0 * rng.uniform();
            let diverge_rate = rng.uniform_in(0.002, 0.008);
            // spiky curves (Figure 1 right): a few configs get heavy noise
            let spiky = rng.uniform() < 0.08;

            for j in 0..EPOCHS {
                let t = (j + 1) as f64;
                let mut acc = a_inf - (a_inf - a_0) * (1.0 + t / tau).powf(-beta);
                if diverges && t > diverge_at {
                    let drop = diverge_rate * (t - diverge_at);
                    acc = (acc - drop).max(0.6 * a_inf);
                }
                let mut eps = noise * rng.normal();
                if spiky && rng.uniform() < 0.12 {
                    eps += rng.normal() * 0.05;
                }
                curves[(i, j)] = (acc + eps).clamp(0.0, 1.0);
            }
        }
        Task {
            name: preset.name().to_string(),
            configs,
            curves,
            epochs: (1..=EPOCHS).map(|e| e as f64).collect(),
            lengths: vec![EPOCHS; n],
        }
    }

    /// Load a real LCBench-style dump:
    /// `{"configs": [[..]], "curves": [[..]], "ids": [..]?}`.
    ///
    /// Curve rows may be ragged (early-stopped configs); the grid length is
    /// the longest row and shorter rows keep their observed prefix length
    /// in [`Task::lengths`]. The loader validates adversarial inputs
    /// instead of panicking or silently mangling them: non-numeric or
    /// non-finite values, ragged config rows, empty curves, and duplicate
    /// `ids` are all hard errors naming the offending row.
    pub fn load_json(name: &str, text: &str) -> crate::Result<Task> {
        let doc = crate::json::Json::parse(text)?;
        let bad = |msg: String| crate::LkgpError::Manifest(format!("task '{name}': {msg}"));
        let rows = |key: &str| -> crate::Result<Vec<Vec<f64>>> {
            doc.get(key)
                .and_then(crate::json::Json::as_arr)
                .ok_or_else(|| bad(format!("missing {key}")))?
                .iter()
                .enumerate()
                .map(|(i, r)| {
                    let xs = r
                        .as_arr()
                        .ok_or_else(|| bad(format!("{key} row {i} is not an array")))?;
                    xs.iter()
                        .map(|v| {
                            let x = v
                                .as_f64()
                                .ok_or_else(|| bad(format!("{key} row {i} has a non-number")))?;
                            if !x.is_finite() {
                                return Err(bad(format!("{key} row {i} has a non-finite value")));
                            }
                            Ok(x)
                        })
                        .collect()
                })
                .collect()
        };
        let configs = rows("configs")?;
        let curves = rows("curves")?;
        if configs.is_empty() {
            return Err(bad("configs is empty".into()));
        }
        if configs.len() != curves.len() {
            return Err(bad(format!(
                "{} configs but {} curves",
                configs.len(),
                curves.len()
            )));
        }
        let d = configs[0].len();
        if d == 0 {
            return Err(bad("config rows are zero-dimensional".into()));
        }
        if let Some(i) = configs.iter().position(|r| r.len() != d) {
            return Err(bad(format!(
                "config row {i} has width {}, expected {d}",
                configs[i].len()
            )));
        }
        if let Some(i) = curves.iter().position(Vec::is_empty) {
            return Err(bad(format!("curve row {i} is empty")));
        }
        if let Some(ids) = doc.get("ids").and_then(crate::json::Json::as_arr) {
            if ids.len() != configs.len() {
                return Err(bad(format!(
                    "{} ids for {} configs",
                    ids.len(),
                    configs.len()
                )));
            }
            let mut seen = std::collections::BTreeSet::new();
            for (i, id) in ids.iter().enumerate() {
                let key = match id {
                    crate::json::Json::Num(x) if x.is_finite() => format!("{x}"),
                    crate::json::Json::Str(s) => s.clone(),
                    _ => return Err(bad(format!("id {i} is neither a number nor a string"))),
                };
                if !seen.insert(key.clone()) {
                    return Err(bad(format!("duplicate config id '{key}' (row {i})")));
                }
            }
        }
        // ragged curves are legal: the grid is the longest row, shorter
        // rows are early-stopped prefixes
        let n = configs.len();
        let m = curves.iter().map(Vec::len).max().unwrap_or(0);
        let mut cm = Matrix::zeros(n, d);
        let mut vm = Matrix::zeros(n, m);
        let mut lengths = Vec::with_capacity(n);
        for i in 0..n {
            cm.row_mut(i).copy_from_slice(&configs[i]);
            vm.row_mut(i)[..curves[i].len()].copy_from_slice(&curves[i]);
            lengths.push(curves[i].len());
        }
        Ok(Task {
            name: name.to_string(),
            configs: cm,
            curves: vm,
            epochs: (1..=m).map(|e| e as f64).collect(),
            lengths,
        })
    }

    pub fn n(&self) -> usize {
        self.configs.rows()
    }

    pub fn m(&self) -> usize {
        self.epochs.len()
    }

    /// Fraction of the (n, m) curve grid that is observed (1.0 when no
    /// row is early-stopped) — the mask density a corpus reports per task.
    pub fn mask_density(&self) -> f64 {
        let total = (self.n() * self.m()).max(1);
        self.lengths.iter().sum::<usize>() as f64 / total as f64
    }
}

/// A partially observed view of a task: the Figure-4 protocol.
///
/// `lengths[i]` epochs of curve i are observed (prefix). Targets are the
/// final-epoch values of the partially observed curves.
#[derive(Clone, Debug)]
pub struct PartialView {
    /// Indices of the drawn configs within the task.
    pub config_idx: Vec<usize>,
    /// Observed prefix length per drawn config (>= 1).
    pub lengths: Vec<usize>,
}

impl PartialView {
    /// Draw a view with ~`budget` total observed values across `k` curves
    /// (ifBO §5.1 protocol: random curves, random cutoffs).
    pub fn sample(task: &Task, k: usize, budget: usize, rng: &mut Pcg64) -> PartialView {
        let k = k.min(task.n());
        let config_idx = rng.sample_indices(task.n(), k);
        // random cutoffs, then rescale to hit the budget approximately
        let mut lengths: Vec<usize> = (0..k).map(|_| 1 + rng.below(task.m() - 1)).collect();
        let total: usize = lengths.iter().sum();
        let scale = budget as f64 / total as f64;
        for len in lengths.iter_mut() {
            *len = ((*len as f64 * scale).round() as usize).clamp(1, task.m() - 1);
        }
        PartialView { config_idx, lengths }
    }

    /// Total observed values.
    pub fn observed(&self) -> usize {
        self.lengths.iter().sum()
    }
}

/// Everything the engines need for one quality-experiment instance, in
/// model space, plus the transforms to undo predictions.
pub struct ModelProblem {
    pub data: Dataset,
    pub xq: Matrix,
    /// Final-epoch ground truth per query (original units).
    pub targets: Vec<f64>,
    pub ytf: YTransform,
}

/// Build the model-space problem for a partial view: train on the observed
/// prefixes, query the *same* configs' final values (the paper's task).
pub fn build_problem(task: &Task, view: &PartialView) -> ModelProblem {
    let k = view.config_idx.len();
    let m = task.m();
    let mut xraw = Matrix::zeros(k, task.configs.cols());
    let mut y = Matrix::zeros(k, m);
    let mut mask = Matrix::zeros(k, m);
    let mut targets = Vec::with_capacity(k);
    for (row, (&ci, &len)) in view.config_idx.iter().zip(&view.lengths).enumerate() {
        xraw.row_mut(row).copy_from_slice(task.configs.row(ci));
        for j in 0..len.min(m) {
            y[(row, j)] = task.curves[(ci, j)];
            mask[(row, j)] = 1.0;
        }
        targets.push(task.curves[(ci, m - 1)]);
    }
    let xtf = XTransform::fit(&xraw);
    let x = xtf.apply(&xraw);
    let ttf = TTransform::fit(&task.epochs);
    let t = ttf.apply(&task.epochs);
    let ytf = YTransform::fit(&y, &mask);
    let ys = ytf.apply(&y, &mask);
    let xq = x.clone(); // query = the same (normalized) configs
    ModelProblem {
        data: Dataset { x, t, y: ys, mask },
        xq,
        targets,
        ytf,
    }
}

/// Small synthetic dataset in model space (tests, smoke commands).
pub fn toy_dataset(n: usize, m: usize, d: usize, seed: u64) -> Dataset {
    let mut rng = Pcg64::new(seed);
    let x = Matrix::from_vec(n, d, rng.uniform_vec(n * d, 0.0, 1.0));
    let t: Vec<f64> = (0..m).map(|i| i as f64 / (m - 1).max(1) as f64).collect();
    let mut mask = Matrix::zeros(n, m);
    for i in 0..n {
        let len = 2 + rng.below(m - 1);
        for j in 0..len {
            mask[(i, j)] = 1.0;
        }
    }
    let mut y = Matrix::zeros(n, m);
    for i in 0..n {
        let a = rng.uniform_in(0.5, 1.0);
        for j in 0..m {
            if mask[(i, j)] > 0.0 {
                y[(i, j)] = -a * (-3.0 * t[j]).exp() + 0.02 * rng.normal();
            }
        }
    }
    Dataset { x, t, y, mask }
}

/// The paper's Figure-3 protocol (§C): X ~ U[0,1]^{n x 10},
/// Y ~ N(0, 1)^{n x m}, t linear on [0, 1], no missing data.
pub fn fig3_dataset(size: usize, rng: &mut Pcg64) -> Dataset {
    let (n, m, d) = (size, size, 10);
    let x = Matrix::from_vec(n, d, rng.uniform_vec(n * d, 0.0, 1.0));
    let t: Vec<f64> = (0..m).map(|i| i as f64 / (m - 1).max(1) as f64).collect();
    let y = Matrix::from_vec(n, m, rng.normal_vec(n * m));
    let mask = Matrix::from_fn(n, m, |_, _| 1.0);
    Dataset { x, t, y, mask }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generate_shapes_and_ranges() {
        let mut rng = Pcg64::new(1);
        let task = Task::generate(Preset::FashionMnist, 50, &mut rng);
        assert_eq!(task.n(), 50);
        assert_eq!(task.m(), EPOCHS);
        for v in task.curves.data() {
            assert!((0.0..=1.0).contains(v));
        }
    }

    #[test]
    fn curves_mostly_improve() {
        let mut rng = Pcg64::new(2);
        let task = Task::generate(Preset::FashionMnist, 100, &mut rng);
        let mut improving = 0;
        for i in 0..100 {
            if task.curves[(i, EPOCHS - 1)] > task.curves[(i, 0)] {
                improving += 1;
            }
        }
        assert!(improving > 75, "{improving}");
    }

    #[test]
    fn presets_have_distinct_accuracy_levels() {
        let mut rng = Pcg64::new(3);
        let fm = Task::generate(Preset::FashionMnist, 80, &mut rng);
        let air = Task::generate(Preset::Airlines, 80, &mut rng);
        let mean_final = |t: &Task| -> f64 {
            (0..t.n()).map(|i| t.curves[(i, EPOCHS - 1)]).sum::<f64>() / t.n() as f64
        };
        assert!(mean_final(&fm) > mean_final(&air) + 0.1);
    }

    #[test]
    fn hyperparams_correlate_with_outcome() {
        // The simulator must create config->curve correlation for the
        // joint GP to exploit: check lr quality effect.
        let mut rng = Pcg64::new(4);
        let task = Task::generate(Preset::FashionMnist, 300, &mut rng);
        let (mut good, mut bad) = (vec![], vec![]);
        for i in 0..task.n() {
            let lr = task.configs[(i, 0)];
            let fin = task.curves[(i, EPOCHS - 1)];
            if (lr + 2.5).abs() < 0.4 {
                good.push(fin);
            } else if lr > -1.4 {
                bad.push(fin);
            }
        }
        let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len().max(1) as f64;
        assert!(mean(&good) > mean(&bad) + 0.02, "{} vs {}", mean(&good), mean(&bad));
    }

    #[test]
    fn partial_view_budget_roughly_met() {
        let mut rng = Pcg64::new(5);
        let task = Task::generate(Preset::Higgs, 100, &mut rng);
        let view = PartialView::sample(&task, 20, 300, &mut rng);
        let obs = view.observed();
        assert!((150..=450).contains(&obs), "{obs}");
        for &l in &view.lengths {
            assert!(l >= 1 && l < task.m());
        }
    }

    #[test]
    fn build_problem_is_consistent() {
        let mut rng = Pcg64::new(6);
        let task = Task::generate(Preset::Airlines, 60, &mut rng);
        let view = PartialView::sample(&task, 12, 200, &mut rng);
        let prob = build_problem(&task, &view);
        assert_eq!(prob.data.n(), 12);
        assert_eq!(prob.data.m(), EPOCHS);
        assert_eq!(prob.xq.rows(), 12);
        assert_eq!(prob.targets.len(), 12);
        prob.data.check().unwrap();
        // mask is prefix per row and matches lengths
        for (row, &len) in view.lengths.iter().enumerate() {
            for j in 0..EPOCHS {
                assert_eq!(prob.data.mask[(row, j)] > 0.0, j < len);
            }
        }
        // y standardized: max over observed == 0
        let max_obs = prob
            .data
            .y
            .data()
            .iter()
            .zip(prob.data.mask.data())
            .filter(|(_, &m)| m > 0.0)
            .map(|(v, _)| *v)
            .fold(f64::NEG_INFINITY, f64::max);
        assert!(max_obs.abs() < 1e-9);
    }

    #[test]
    fn json_roundtrip() {
        let text = r#"{"configs": [[0.1, 0.2], [0.3, 0.4]],
                       "curves": [[0.5, 0.6, 0.7], [0.4, 0.5, 0.55]]}"#;
        let task = Task::load_json("custom", text).unwrap();
        assert_eq!(task.n(), 2);
        assert_eq!(task.m(), 3);
        assert_eq!(task.curves[(1, 2)], 0.55);
        assert_eq!(task.lengths, vec![3, 3]);
        assert_eq!(task.mask_density(), 1.0);
        assert!(Task::load_json("bad", "{\"configs\": []}").is_err());
    }

    #[test]
    fn json_ragged_curves_are_early_stopped_prefixes() {
        let text = r#"{"configs": [[0.1], [0.2], [0.3]],
                       "curves": [[0.5, 0.6, 0.7, 0.8], [0.4], [0.3, 0.35]]}"#;
        let task = Task::load_json("ragged", text).unwrap();
        assert_eq!(task.m(), 4);
        assert_eq!(task.lengths, vec![4, 1, 2]);
        // padding past the observed prefix is zero
        assert_eq!(task.curves[(1, 1)], 0.0);
        assert_eq!(task.curves[(2, 2)], 0.0);
        assert!((task.mask_density() - 7.0 / 12.0).abs() < 1e-12);
    }

    #[test]
    fn fig3_protocol_shapes() {
        let mut rng = Pcg64::new(7);
        let data = fig3_dataset(16, &mut rng);
        assert_eq!(data.n(), 16);
        assert_eq!(data.m(), 16);
        assert_eq!(data.d(), 10);
        assert_eq!(data.n_obs(), 256.0);
    }
}
