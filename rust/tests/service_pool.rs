//! Multi-task sharded serving e2e: the pool must be a behavior-preserving
//! deployment of N standalone services — same predictions, same scheduler
//! decisions — plus warm-start and backpressure behavior on top.

use std::sync::atomic::Ordering;

use lkgp::coordinator::{
    CurveStore, EpochRunner, PoolCfg, PredictClient, PredictionService, Registry, Scheduler,
    SchedulerCfg, ServicePool, Snapshot, TrialId,
};
use lkgp::gp::Theta;
use lkgp::lcbench::{Preset, Task};
use lkgp::linalg::Matrix;
use lkgp::rng::Pcg64;
use lkgp::runtime::{Engine, RustEngine};

/// Registry snapshot of a simulated task with prefix-observed curves.
fn snapshot_for(preset: Preset, n: usize, seed: u64) -> Snapshot {
    let mut rng = Pcg64::new(seed);
    let task = Task::generate(preset, n, &mut rng);
    let mut reg = Registry::new();
    for i in 0..n {
        let id = reg.add(task.configs.row(i).to_vec());
        let len = 3 + rng.below(8);
        for j in 0..len {
            reg.observe(id, task.curves[(i, j)], task.m()).unwrap();
        }
    }
    CurveStore::new(task.m()).snapshot(&reg).unwrap()
}

fn rust_engines(n: usize) -> Vec<Box<dyn Engine>> {
    (0..n)
        .map(|_| Box::<RustEngine>::default() as Box<dyn Engine>)
        .collect()
}

/// Two shards on different LCBench presets, concurrent callers, fixed
/// seeds: per-task predictions must be *identical* to running each task
/// through a standalone single-task service.
#[test]
fn concurrent_pool_predictions_identical_to_standalone_services() {
    let presets = [Preset::FashionMnist, Preset::Higgs];
    let snaps: Vec<Snapshot> = presets
        .iter()
        .enumerate()
        .map(|(t, &p)| snapshot_for(p, 10, 40 + t as u64))
        .collect();
    let theta = Theta::default_packed(7);
    let callers = 5;

    // standalone reference: one cold service per task, sequential callers
    let mut want: Vec<Vec<Vec<(f64, f64)>>> = Vec::new();
    for snap in &snaps {
        let service = PredictionService::spawn(Box::<RustEngine>::default());
        let mut per_task = Vec::new();
        for c in 0..callers {
            let xq = Matrix::from_vec(1, 7, snap.all_x.row(c).to_vec());
            per_task.push(
                service
                    .predict_final(snap.clone(), theta.clone(), xq)
                    .unwrap(),
            );
        }
        want.push(per_task);
    }

    // pool: same queries, but issued by concurrent caller threads against
    // two shards at once. warm_start off keeps every solve cold, so any
    // coalescing/batch split is behavior-neutral (batched CG elements are
    // independent).
    let pool = ServicePool::spawn(
        rust_engines(2),
        PoolCfg { workers: 4, warm_start: false, ..Default::default() },
    );
    let got: Vec<Vec<Vec<(f64, f64)>>> = std::thread::scope(|scope| {
        let theta = &theta;
        let mut joins = Vec::new();
        for (t, snap) in snaps.iter().enumerate() {
            let handle = pool.handle(t);
            joins.push(scope.spawn(move || {
                let mut per_task = Vec::new();
                for c in 0..callers {
                    let xq = Matrix::from_vec(1, 7, snap.all_x.row(c).to_vec());
                    per_task.push(
                        handle
                            .predict_final(snap.clone(), theta.clone(), xq)
                            .unwrap(),
                    );
                }
                per_task
            }));
        }
        joins.into_iter().map(|j| j.join().unwrap()).collect()
    });

    assert_eq!(got, want, "pool predictions diverge from standalone");
}

struct SimRunner {
    task: Task,
}

impl EpochRunner for SimRunner {
    fn run_epoch(&mut self, trial: TrialId, _config: &[f64], epoch: usize) -> f64 {
        self.task.curves[(trial.0, epoch.min(self.task.m() - 1))]
    }
}

fn scheduler_for(task: &Task, seed: u64) -> Scheduler {
    let cfg = SchedulerCfg {
        max_concurrent: 3,
        refit_every: 4,
        epoch_budget: 70,
        seed,
        ..Default::default()
    };
    let mut sched = Scheduler::new(task.m(), cfg);
    let configs: Vec<Vec<f64>> = (0..task.n()).map(|i| task.configs.row(i).to_vec()).collect();
    sched.add_candidates(&configs);
    sched
}

/// Full freeze-thaw loops on two pool shards running concurrently must
/// reproduce the standalone runs round for round.
#[test]
fn two_shard_schedulers_match_standalone_runs() {
    let presets = [Preset::FashionMnist, Preset::Airlines];

    // standalone reference runs
    let mut want = Vec::new();
    for (t, &preset) in presets.iter().enumerate() {
        let mut rng = Pcg64::new(7 + t as u64);
        let task = Task::generate(preset, 10, &mut rng);
        let mut sched = scheduler_for(&task, 7 + t as u64);
        let service = PredictionService::spawn(Box::<RustEngine>::default());
        let mut runner = SimRunner { task };
        want.push(sched.run(&mut runner, &service).unwrap());
    }

    // concurrent pool runs (cold shards = standalone semantics)
    let pool = ServicePool::spawn(
        rust_engines(2),
        PoolCfg { workers: 2, warm_start: false, ..Default::default() },
    );
    let got: Vec<lkgp::coordinator::RunReport> = std::thread::scope(|scope| {
        let mut joins = Vec::new();
        for (t, &preset) in presets.iter().enumerate() {
            let handle = pool.handle(t);
            joins.push(scope.spawn(move || {
                let mut rng = Pcg64::new(7 + t as u64);
                let task = Task::generate(preset, 10, &mut rng);
                let mut sched = scheduler_for(&task, 7 + t as u64);
                let mut runner = SimRunner { task };
                sched.run(&mut runner, &handle).unwrap()
            }));
        }
        joins.into_iter().map(|j| j.join().unwrap()).collect()
    });

    for (g, w) in got.iter().zip(&want) {
        assert_eq!(g.epochs_spent, w.epochs_spent);
        assert_eq!(g.rounds, w.rounds);
        assert_eq!(g.best_value, w.best_value);
        assert_eq!(g.trace, w.trace);
    }
}

/// Warm-started shards must stay within solver tolerance of cold results
/// across generations, and actually hit their cache.
#[test]
fn warm_shard_tracks_cold_service_across_generations() {
    let mut rng = Pcg64::new(9);
    let task = Task::generate(Preset::FashionMnist, 10, &mut rng);
    let mut reg = Registry::new();
    for i in 0..task.n() {
        let id = reg.add(task.configs.row(i).to_vec());
        for j in 0..4 {
            reg.observe(id, task.curves[(i, j)], task.m()).unwrap();
        }
    }
    let mut store = CurveStore::new(task.m());
    let snap1 = store.snapshot(&reg).unwrap();
    let theta = Theta::default_packed(7);
    let xq = Matrix::from_vec(2, 7, {
        let mut v = snap1.all_x.row(0).to_vec();
        v.extend_from_slice(snap1.all_x.row(1));
        v
    });

    let pool = ServicePool::spawn(
        rust_engines(1),
        PoolCfg { workers: 1, warm_start: true, ..Default::default() },
    );
    let handle = pool.handle(0);
    let p1 = handle
        .predict_final(snap1.clone(), theta.clone(), xq.clone())
        .unwrap();
    // next generation: every trial trains one more epoch
    for i in 0..task.n() {
        reg.observe(TrialId(i), task.curves[(i, 4)], task.m()).unwrap();
    }
    let snap2 = store.snapshot(&reg).unwrap();
    let p2 = handle
        .predict_final(snap2.clone(), theta.clone(), xq.clone())
        .unwrap();
    assert!(pool.stats(0).warm_hits.load(Ordering::Relaxed) >= 1);

    // cold reference on the new generation
    let service = PredictionService::spawn(Box::<RustEngine>::default());
    let cold = service.predict_final(snap2, theta, xq).unwrap();
    for (w, c) in p2.iter().zip(&cold) {
        assert!(
            (w.0 - c.0).abs() < 0.1 && (w.1 - c.1).abs() < 0.1,
            "warm {w:?} vs cold {c:?}"
        );
    }
    // sanity: generation-1 predictions were finite and plausible too
    for (mu, var) in p1 {
        assert!(mu.is_finite() && var > 0.0);
    }
}

/// Backpressure: a slow shard's queue is bounded by `max_queue` and every
/// request still completes.
#[test]
fn backpressure_bounds_queue_depth() {
    let snap = snapshot_for(Preset::Airlines, 8, 11);
    let theta = Theta::default_packed(7);
    let pool = ServicePool::spawn(
        rust_engines(1),
        PoolCfg { workers: 1, max_queue: 4, ..Default::default() },
    );
    let mut receivers = Vec::new();
    for c in 0..20 {
        let (rtx, rrx) = std::sync::mpsc::channel();
        pool.submit(
            0,
            lkgp::coordinator::Request::PredictFinal {
                snapshot: snap.clone(),
                theta: theta.clone(),
                xq: Matrix::from_vec(1, 7, snap.all_x.row(c % 8).to_vec()),
                resp: rtx,
            },
        )
        .unwrap();
        receivers.push(rrx);
    }
    for r in receivers {
        let preds = r.recv().unwrap().unwrap();
        assert_eq!(preds.len(), 1);
    }
    let peak = pool.stats(0).peak_queue_depth.load(Ordering::Relaxed);
    assert!(peak <= 4, "peak queue depth {peak} exceeds bound");
    assert_eq!(pool.stats(0).enqueued.load(Ordering::Relaxed), 20);
}
