//! Multi-task sharded serving e2e: the pool must be a behavior-preserving
//! deployment of N standalone services — same predictions, same scheduler
//! decisions — plus warm-start and backpressure behavior on top.

use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc};
use std::time::Duration;

use lkgp::coordinator::{
    Answer, CurveStore, EpochRunner, PoolCfg, PredictClient, PredictionService, Query, Registry,
    Request, Scheduler, SchedulerCfg, ServicePool, Snapshot, TrialId,
};
use lkgp::gp::transforms::YTransform;
use lkgp::gp::{Dataset, SolverCfg, Theta};
use lkgp::lcbench::{Preset, Task};
use lkgp::linalg::Matrix;
use lkgp::rng::Pcg64;
use lkgp::runtime::{Engine, RustEngine};

/// Registry snapshot of a simulated task with prefix-observed curves.
fn snapshot_for(preset: Preset, n: usize, seed: u64) -> Snapshot {
    let mut rng = Pcg64::new(seed);
    let task = Task::generate(preset, n, &mut rng);
    let mut reg = Registry::new();
    for i in 0..n {
        let id = reg.add(task.configs.row(i).to_vec());
        let len = 3 + rng.below(8);
        for j in 0..len {
            reg.observe(id, task.curves[(i, j)], task.m()).unwrap();
        }
    }
    CurveStore::new(task.m()).snapshot(&reg).unwrap()
}

fn rust_engines(n: usize) -> Vec<Box<dyn Engine>> {
    (0..n)
        .map(|_| Box::<RustEngine>::default() as Box<dyn Engine>)
        .collect()
}

/// Two shards on different LCBench presets, concurrent callers, fixed
/// seeds: per-task predictions must be *identical* to running each task
/// through a standalone single-task service.
#[test]
fn concurrent_pool_predictions_identical_to_standalone_services() {
    let presets = [Preset::FashionMnist, Preset::Higgs];
    let snaps: Vec<Snapshot> = presets
        .iter()
        .enumerate()
        .map(|(t, &p)| snapshot_for(p, 10, 40 + t as u64))
        .collect();
    let theta = Theta::default_packed(7);
    let callers = 5;

    // standalone reference: one cold service per task, sequential callers
    let mut want: Vec<Vec<Vec<(f64, f64)>>> = Vec::new();
    for snap in &snaps {
        let service = PredictionService::spawn(Box::<RustEngine>::default());
        let mut per_task = Vec::new();
        for c in 0..callers {
            let xq = Matrix::from_vec(1, 7, snap.all_x.row(c).to_vec());
            per_task.push(
                service
                    .predict_final(snap.clone(), theta.clone(), xq)
                    .unwrap(),
            );
        }
        want.push(per_task);
    }

    // pool: same queries, but issued by concurrent caller threads against
    // two shards at once. warm_start off keeps every solve cold, so any
    // coalescing/batch split is behavior-neutral (batched CG elements are
    // independent).
    let pool = ServicePool::spawn(
        rust_engines(2),
        PoolCfg { workers: 4, warm_start: false, ..Default::default() },
    );
    let got: Vec<Vec<Vec<(f64, f64)>>> = std::thread::scope(|scope| {
        let theta = &theta;
        let mut joins = Vec::new();
        for (t, snap) in snaps.iter().enumerate() {
            let handle = pool.handle(t);
            joins.push(scope.spawn(move || {
                let mut per_task = Vec::new();
                for c in 0..callers {
                    let xq = Matrix::from_vec(1, 7, snap.all_x.row(c).to_vec());
                    per_task.push(
                        handle
                            .predict_final(snap.clone(), theta.clone(), xq)
                            .unwrap(),
                    );
                }
                per_task
            }));
        }
        joins.into_iter().map(|j| j.join().unwrap()).collect()
    });

    assert_eq!(got, want, "pool predictions diverge from standalone");
}

struct SimRunner {
    task: Task,
}

impl EpochRunner for SimRunner {
    fn run_epoch(&mut self, trial: TrialId, _config: &[f64], epoch: usize) -> f64 {
        self.task.curves[(trial.0, epoch.min(self.task.m() - 1))]
    }
}

fn scheduler_for(task: &Task, seed: u64) -> Scheduler {
    let cfg = SchedulerCfg {
        max_concurrent: 3,
        refit_every: 4,
        epoch_budget: 70,
        seed,
        ..Default::default()
    };
    let mut sched = Scheduler::new(task.m(), cfg);
    let configs: Vec<Vec<f64>> = (0..task.n()).map(|i| task.configs.row(i).to_vec()).collect();
    sched.add_candidates(&configs);
    sched
}

/// Full freeze-thaw loops on two pool shards running concurrently must
/// reproduce the standalone runs round for round.
#[test]
fn two_shard_schedulers_match_standalone_runs() {
    let presets = [Preset::FashionMnist, Preset::Airlines];

    // standalone reference runs
    let mut want = Vec::new();
    for (t, &preset) in presets.iter().enumerate() {
        let mut rng = Pcg64::new(7 + t as u64);
        let task = Task::generate(preset, 10, &mut rng);
        let mut sched = scheduler_for(&task, 7 + t as u64);
        let service = PredictionService::spawn(Box::<RustEngine>::default());
        let mut runner = SimRunner { task };
        want.push(sched.run(&mut runner, &service).unwrap());
    }

    // concurrent pool runs (cold shards = standalone semantics)
    let pool = ServicePool::spawn(
        rust_engines(2),
        PoolCfg { workers: 2, warm_start: false, ..Default::default() },
    );
    let got: Vec<lkgp::coordinator::RunReport> = std::thread::scope(|scope| {
        let mut joins = Vec::new();
        for (t, &preset) in presets.iter().enumerate() {
            let handle = pool.handle(t);
            joins.push(scope.spawn(move || {
                let mut rng = Pcg64::new(7 + t as u64);
                let task = Task::generate(preset, 10, &mut rng);
                let mut sched = scheduler_for(&task, 7 + t as u64);
                let mut runner = SimRunner { task };
                sched.run(&mut runner, &handle).unwrap()
            }));
        }
        joins.into_iter().map(|j| j.join().unwrap()).collect()
    });

    for (g, w) in got.iter().zip(&want) {
        assert_eq!(g.epochs_spent, w.epochs_spent);
        assert_eq!(g.rounds, w.rounds);
        assert_eq!(g.best_value, w.best_value);
        assert_eq!(g.trace, w.trace);
    }
}

/// Warm-started shards must stay within solver tolerance of cold results
/// across generations, and actually hit their cache.
#[test]
fn warm_shard_tracks_cold_service_across_generations() {
    let mut rng = Pcg64::new(9);
    let task = Task::generate(Preset::FashionMnist, 10, &mut rng);
    let mut reg = Registry::new();
    for i in 0..task.n() {
        let id = reg.add(task.configs.row(i).to_vec());
        for j in 0..4 {
            reg.observe(id, task.curves[(i, j)], task.m()).unwrap();
        }
    }
    let mut store = CurveStore::new(task.m());
    let snap1 = store.snapshot(&reg).unwrap();
    let theta = Theta::default_packed(7);
    let xq = Matrix::from_vec(2, 7, {
        let mut v = snap1.all_x.row(0).to_vec();
        v.extend_from_slice(snap1.all_x.row(1));
        v
    });

    let pool = ServicePool::spawn(
        rust_engines(1),
        PoolCfg { workers: 1, warm_start: true, ..Default::default() },
    );
    let handle = pool.handle(0);
    let p1 = handle
        .predict_final(snap1.clone(), theta.clone(), xq.clone())
        .unwrap();
    // next generation: every trial trains one more epoch
    for i in 0..task.n() {
        reg.observe(TrialId(i), task.curves[(i, 4)], task.m()).unwrap();
    }
    let snap2 = store.snapshot(&reg).unwrap();
    let p2 = handle
        .predict_final(snap2.clone(), theta.clone(), xq.clone())
        .unwrap();
    assert!(pool.stats(0).warm_hits.load(Ordering::Relaxed) >= 1);

    // cold reference on the new generation
    let service = PredictionService::spawn(Box::<RustEngine>::default());
    let cold = service.predict_final(snap2, theta, xq).unwrap();
    for (w, c) in p2.iter().zip(&cold) {
        assert!(
            (w.0 - c.0).abs() < 0.1 && (w.1 - c.1).abs() < 0.1,
            "warm {w:?} vs cold {c:?}"
        );
    }
    // sanity: generation-1 predictions were finite and plausible too
    for (mu, var) in p1 {
        assert!(mu.is_finite() && var > 0.0);
    }
}

/// Backpressure: a slow shard's queue is bounded by `max_queue` and every
/// request still completes.
#[test]
fn backpressure_bounds_queue_depth() {
    let snap = snapshot_for(Preset::Airlines, 8, 11);
    let theta = Theta::default_packed(7);
    let pool = ServicePool::spawn(
        rust_engines(1),
        PoolCfg { workers: 1, max_queue: 4, ..Default::default() },
    );
    let mut receivers = Vec::new();
    for c in 0..20 {
        let (rtx, rrx) = std::sync::mpsc::channel();
        pool.submit(
            0,
            lkgp::coordinator::Request::PredictFinal {
                snapshot: snap.clone(),
                theta: theta.clone(),
                xq: Matrix::from_vec(1, 7, snap.all_x.row(c % 8).to_vec()),
                resp: rtx,
            },
        )
        .unwrap();
        receivers.push(rrx);
    }
    for r in receivers {
        let preds = r.recv().unwrap().unwrap();
        assert_eq!(preds.len(), 1);
    }
    let peak = pool.stats(0).peak_queue_depth.load(Ordering::Relaxed);
    assert!(peak <= 4, "peak queue depth {peak} exceeds bound");
    assert_eq!(pool.stats(0).enqueued.load(Ordering::Relaxed), 20);
}

// ---------------------------------------------------------------------------
// Read-only replica shards

/// A `RustEngine` whose `fit` blocks until the test sends a token: the
/// deterministic way to pin a pool's writer on a "slow refit" while
/// read-only traffic queues up behind it.
struct GatedEngine {
    inner: RustEngine,
    gate: mpsc::Receiver<()>,
}

impl GatedEngine {
    fn pair() -> (mpsc::Sender<()>, Box<dyn Engine>) {
        let (tx, rx) = mpsc::channel();
        (tx, Box::new(GatedEngine { inner: RustEngine::default(), gate: rx }))
    }
}

impl Engine for GatedEngine {
    fn fit(&mut self, theta0: &[f64], data: &Dataset, seed: u64) -> lkgp::Result<Vec<f64>> {
        let _ = self.gate.recv();
        self.inner.fit(theta0, data, seed)
    }

    fn predict_final(
        &mut self,
        theta: &[f64],
        data: &Dataset,
        xq: &Matrix,
    ) -> lkgp::Result<Vec<(f64, f64)>> {
        self.inner.predict_final(theta, data, xq)
    }

    fn answer_batch(
        &mut self,
        theta: &[f64],
        data: &Arc<Dataset>,
        queries: &[Query],
        warm: Option<&[f64]>,
        precond: Option<Arc<lkgp::gp::PrecondFactors>>,
        path: Option<lkgp::gp::PathLineage>,
    ) -> lkgp::Result<lkgp::runtime::QueryOutcome> {
        self.inner.answer_batch(theta, data, queries, warm, precond, path)
    }

    fn sample_curves(
        &mut self,
        theta: &[f64],
        data: &Dataset,
        xq: &Matrix,
        s: usize,
        seed: u64,
    ) -> lkgp::Result<Vec<Matrix>> {
        self.inner.sample_curves(theta, data, xq, s, seed)
    }

    fn predict_mean(&mut self, theta: &[f64], data: &Dataset, xq: &Matrix) -> lkgp::Result<Matrix> {
        self.inner.predict_mean(theta, data, xq)
    }

    fn session_cfg(&self) -> Option<SolverCfg> {
        self.inner.session_cfg()
    }

    fn name(&self) -> &'static str {
        "gated"
    }
}

fn assert_answers_bit_equal(got: &[Answer], want: &[Answer]) {
    assert_eq!(got.len(), want.len());
    for (g, w) in got.iter().zip(want) {
        match (g, w) {
            (Answer::Final(a), Answer::Final(b)) => {
                assert_eq!(a.len(), b.len());
                for (x, y) in a.iter().zip(b) {
                    assert_eq!(x.0.to_bits(), y.0.to_bits(), "mean diverged");
                    assert_eq!(x.1.to_bits(), y.1.to_bits(), "variance diverged");
                }
            }
            (Answer::Variance(a), Answer::Variance(b)) => {
                assert_eq!(a.len(), b.len());
                for (x, y) in a.iter().zip(b) {
                    assert_eq!(x.to_bits(), y.to_bits(), "variance diverged");
                }
            }
            (Answer::Quantiles(a), Answer::Quantiles(b))
            | (Answer::Steps(a), Answer::Steps(b)) => {
                assert_eq!((a.rows(), a.cols()), (b.rows(), b.cols()));
                for (x, y) in a.data().iter().zip(b.data()) {
                    assert_eq!(x.to_bits(), y.to_bits(), "matrix answer diverged");
                }
            }
            other => panic!("answer kinds diverged: {other:?}"),
        }
    }
}

/// Pin the writer on a gated refit and wait until a worker claims it.
fn pin_writer(
    pool: &ServicePool,
    snap: &Snapshot,
    theta: &[f64],
) -> mpsc::Receiver<lkgp::Result<Vec<f64>>> {
    let (ftx, frx) = mpsc::channel();
    pool.submit(
        0,
        Request::Refit {
            snapshot: snap.clone(),
            theta0: theta.to_vec(),
            seed: 3,
            resp: ftx,
        },
    )
    .unwrap();
    while pool.queue_depth(0) > 0 {
        std::thread::yield_now();
    }
    frx
}

/// While the writer is pinned on a refit, a burst of read-only query
/// batches for the already-fitted generation must be served by replicas:
/// bit-identical to the writer's answers, with ZERO additional underlying
/// solves (the lineage fast path) and no retires.
#[test]
fn replica_serves_read_burst_while_writer_is_busy() {
    let (gate, engine) = GatedEngine::pair();
    let pool = ServicePool::spawn(
        vec![engine],
        PoolCfg { workers: 2, warm_start: true, max_replicas: 2, ..Default::default() },
    );
    let snap = snapshot_for(Preset::FashionMnist, 10, 21);
    let theta = Theta::default_packed(7);
    let xq = Matrix::from_vec(2, 7, {
        let mut v = snap.all_x.row(0).to_vec();
        v.extend_from_slice(snap.all_x.row(3));
        v
    });
    let queries = vec![
        Query::MeanAtFinal { xq: xq.clone() },
        Query::Variance { xq: xq.clone() },
        Query::Quantiles { xq, ps: vec![0.1, 0.9] },
    ];
    let handle = pool.handle(0);
    // writer fits the generation once; its answers are the parity oracle
    let want = handle.query(snap.clone(), theta.clone(), queries.clone()).unwrap();
    let solves_before = pool.stats(0).engine_solves.load(Ordering::Relaxed);

    let frx = pin_writer(&pool, &snap, &theta);
    let mut rxs = Vec::new();
    for _ in 0..4 {
        let (rtx, rrx) = mpsc::channel();
        pool.submit(
            0,
            Request::Query {
                snapshot: snap.clone(),
                theta: theta.clone(),
                queries: queries.clone(),
                resp: rtx,
            },
        )
        .unwrap();
        rxs.push(rrx);
    }
    for rrx in rxs {
        let got = rrx
            .recv_timeout(Duration::from_secs(60))
            .expect("replicas must serve reads while the writer is busy")
            .unwrap();
        assert_answers_bit_equal(&got, &want);
    }
    let stats = pool.stats(0);
    assert!(
        stats.replica_hits.load(Ordering::Relaxed) >= 1,
        "burst must be replica-served"
    );
    assert_eq!(
        stats.engine_solves.load(Ordering::Relaxed),
        solves_before,
        "lineage-covered replica burst must add zero solves"
    );
    assert_eq!(stats.stale_replica_retires.load(Ordering::Relaxed), 0);
    gate.send(()).unwrap();
    frx.recv().unwrap().unwrap();
}

/// A writer advancing the generation mid-burst must retire the replica:
/// its computed answers are discarded (never delivered), the requests go
/// back to the writer, and `stale_replica_retires` counts the event.
#[test]
fn stale_replica_retires_when_writer_advances_mid_burst() {
    let (gate, engine) = GatedEngine::pair();
    let pool = ServicePool::spawn(
        vec![engine],
        PoolCfg { workers: 2, warm_start: true, max_replicas: 2, ..Default::default() },
    );
    let mut rng = Pcg64::new(9);
    let task = Task::generate(Preset::Higgs, 24, &mut rng);
    let mut reg = Registry::new();
    for i in 0..task.n() {
        let id = reg.add(task.configs.row(i).to_vec());
        for j in 0..4 {
            reg.observe(id, task.curves[(i, j)], task.m()).unwrap();
        }
    }
    let mut store = CurveStore::new(task.m());
    let snap1 = store.snapshot(&reg).unwrap();
    // build generation 2 UP FRONT so that, once the steal is observed,
    // advancing the fence is a single submit call (microseconds) while
    // the replica is still inside a many-millisecond sampling solve
    for i in 0..task.n() {
        reg.observe(TrialId(i), task.curves[(i, 4)], task.m()).unwrap();
    }
    let snap2 = store.snapshot(&reg).unwrap();
    let theta = Theta::default_packed(7);
    let xq = Matrix::from_vec(1, 7, snap1.all_x.row(0).to_vec());
    let handle = pool.handle(0);
    let want = handle
        .query(snap1.clone(), theta.clone(), vec![Query::MeanAtFinal { xq: xq.clone() }])
        .unwrap();

    let frx1 = pin_writer(&pool, &snap1, &theta);
    // a deliberately heavy read (big pathwise sampling solve) so the
    // fence can move while the replica is mid-computation
    let (rtx, rrx) = mpsc::channel();
    pool.submit(
        0,
        Request::Query {
            snapshot: snap1.clone(),
            theta: theta.clone(),
            queries: vec![
                Query::CurveSamples { xq: xq.clone(), n: 128, seed: 5 },
                Query::MeanAtFinal { xq: xq.clone() },
            ],
            resp: rtx,
        },
    )
    .unwrap();
    // wait until a replica stole the read (the writer is pinned, so only
    // a replica can empty the queue) ...
    while pool.queue_depth(0) > 0 {
        std::thread::yield_now();
    }
    // ... then advance the generation fence with a queued write
    let (f2tx, f2rx) = mpsc::channel();
    pool.submit(
        0,
        Request::Refit { snapshot: snap2, theta0: theta.clone(), seed: 4, resp: f2tx },
    )
    .unwrap();
    // release both gated refits; the retired read is answered by the
    // writer afterwards
    gate.send(()).unwrap();
    gate.send(()).unwrap();
    let answers = rrx
        .recv_timeout(Duration::from_secs(120))
        .expect("retired reads must still be answered (by the writer)")
        .unwrap();
    assert_eq!(answers.len(), 2);
    assert!(
        pool.stats(0).stale_replica_retires.load(Ordering::Relaxed) >= 1,
        "the replica must retire when the fence advances mid-burst"
    );
    // the writer's answer for the retired read matches its own earlier
    // answer for the same (generation, theta, query) to solver tolerance
    match (&answers[1], &want[0]) {
        (Answer::Final(a), Answer::Final(b)) => {
            assert!((a[0].0 - b[0].0).abs() < 1e-6 && (a[0].1 - b[0].1).abs() < 1e-6);
        }
        other => panic!("unexpected answers {other:?}"),
    }
    frx1.recv().unwrap().unwrap();
    f2rx.recv().unwrap().unwrap();
}

/// A task whose dataset carries a fully-masked row (registered but never
/// observed at the model level) must be servable through a replica, with
/// answers bit-identical to the writer's.
#[test]
fn fully_masked_row_task_served_via_replica() {
    let (n, m, d) = (5usize, 6usize, 2usize);
    let mut rng = Pcg64::new(31);
    let x = Matrix::from_vec(n, d, rng.uniform_vec(n * d, 0.0, 1.0));
    let t: Vec<f64> = (0..m).map(|j| j as f64 / (m - 1) as f64).collect();
    let mut y = Matrix::zeros(n, m);
    let mut mask = Matrix::zeros(n, m);
    for i in 0..n {
        if i == 3 {
            continue; // row 3 stays fully masked
        }
        for j in 0..2 + i % 3 {
            mask[(i, j)] = 1.0;
            y[(i, j)] = -0.4 + 0.08 * j as f64 + 0.01 * i as f64;
        }
    }
    let ids: Vec<TrialId> = (0..n).map(TrialId).collect();
    let snap = Snapshot {
        generation: 1,
        data: Arc::new(Dataset { x: x.clone(), t, y: y.clone(), mask: mask.clone() }),
        row_ids: Arc::new(ids.clone()),
        all_x: Arc::new(x),
        all_ids: Arc::new(ids),
        ytf: Arc::new(YTransform::fit(&y, &mask)),
        warm: None,
    };
    let theta = Theta::default_packed(d);
    let xq = Matrix::from_vec(1, d, vec![0.4, 0.6]);
    let queries = vec![
        Query::MeanAtFinal { xq: xq.clone() },
        Query::MeanAtSteps { xq, steps: vec![0, m - 1] },
    ];

    let (gate, engine) = GatedEngine::pair();
    let pool = ServicePool::spawn(
        vec![engine],
        PoolCfg { workers: 2, warm_start: true, max_replicas: 2, ..Default::default() },
    );
    let handle = pool.handle(0);
    let want = handle.query(snap.clone(), theta.clone(), queries.clone()).unwrap();

    let frx = pin_writer(&pool, &snap, &theta);
    let (rtx, rrx) = mpsc::channel();
    pool.submit(
        0,
        Request::Query {
            snapshot: snap.clone(),
            theta: theta.clone(),
            queries: queries.clone(),
            resp: rtx,
        },
    )
    .unwrap();
    let got = rrx
        .recv_timeout(Duration::from_secs(60))
        .expect("replica must serve the fully-masked-row task")
        .unwrap();
    assert_answers_bit_equal(&got, &want);
    assert!(pool.stats(0).replica_hits.load(Ordering::Relaxed) >= 1);
    gate.send(()).unwrap();
    frx.recv().unwrap().unwrap();
}

/// Intra-batch split: a single oversized stacked query batch submitted
/// through a shard handle with a small `split_rows` threshold must (a)
/// fan into multiple queued requests (observable via `split_batches` and
/// `enqueued`), and (b) return answers bit-identical to the same batch on
/// an unsplit pool — cold solves make batched-CG composition
/// behavior-neutral, so chunking must not change a single bit.
#[test]
fn oversized_batch_split_matches_unsplit_bitwise() {
    let snap = snapshot_for(Preset::FashionMnist, 12, 77);
    let theta = Theta::default_packed(7);
    let big_xq = Matrix::from_vec(6, 7, {
        let mut v = Vec::new();
        for r in 0..6 {
            v.extend_from_slice(snap.all_x.row(r));
        }
        v
    });
    let small_xq = Matrix::from_vec(2, 7, {
        let mut v = snap.all_x.row(6).to_vec();
        v.extend_from_slice(snap.all_x.row(7));
        v
    });
    let queries = vec![
        Query::MeanAtFinal { xq: big_xq.clone() },
        Query::Variance { xq: small_xq.clone() },
        Query::Quantiles { xq: big_xq, ps: vec![0.25, 0.75] },
        Query::MeanAtFinal { xq: small_xq },
    ];

    // reference: splitting disabled, cold solves
    let whole = ServicePool::spawn(
        rust_engines(1),
        PoolCfg { workers: 2, warm_start: false, split_rows: 0, ..Default::default() },
    );
    let want = whole
        .handle(0)
        .query(snap.clone(), theta.clone(), queries.clone())
        .unwrap();
    assert_eq!(whole.stats(0).split_batches.load(Ordering::Relaxed), 0);
    assert_eq!(whole.stats(0).enqueued.load(Ordering::Relaxed), 1);

    // split pool: weights are 6, 2, 6, 2 -> threshold 8 chunks as [6+2][6+2]
    let split = ServicePool::spawn(
        rust_engines(1),
        PoolCfg { workers: 2, warm_start: false, split_rows: 8, ..Default::default() },
    );
    let got = split
        .handle(0)
        .query(snap.clone(), theta.clone(), queries.clone())
        .unwrap();
    assert_eq!(split.stats(0).split_batches.load(Ordering::Relaxed), 1);
    assert!(
        split.stats(0).enqueued.load(Ordering::Relaxed) >= 2,
        "split batch must enqueue one request per chunk"
    );
    assert_answers_bit_equal(&got, &want);
}

/// Observe-then-query must be bit-identical to a refit-free from-scratch
/// pool serving the same extended snapshot: both pools pay the same cold
/// gen-1 solve, and the gen-2 training solve runs from the same embedded
/// gen-1 alpha, operator, preconditioner, and tolerance whether it is
/// triggered by an `Observe` or by the query itself — so every answer bit
/// matches (the ISSUE's oracle acceptance). The gen-2 query uses different
/// query rows than gen-1 so neither pool can ride a cached cross-solve.
#[test]
fn observe_then_query_bit_identical_to_from_scratch_on_extended_mask() {
    let mut rng = Pcg64::new(17);
    let task = Task::generate(Preset::FashionMnist, 8, &mut rng);
    let mut reg = Registry::new();
    for i in 0..task.n() {
        let id = reg.add(task.configs.row(i).to_vec());
        for j in 0..4 {
            reg.observe(id, task.curves[(i, j)], task.m()).unwrap();
        }
    }
    let mut store = CurveStore::new(task.m());
    let snap1 = store.snapshot(&reg).unwrap();
    for i in 0..task.n() {
        reg.observe(TrialId(i), task.curves[(i, 4)], task.m()).unwrap();
    }
    let snap2 = store.snapshot(&reg).unwrap();
    let theta = Theta::default_packed(7);
    let xq1 = Matrix::from_vec(1, 7, snap1.all_x.row(0).to_vec());
    let xq2 = Matrix::from_vec(1, 7, snap1.all_x.row(3).to_vec());

    let mk_pool = || {
        ServicePool::spawn(
            rust_engines(1),
            PoolCfg { workers: 1, warm_start: true, max_replicas: 0, ..Default::default() },
        )
    };
    let a = mk_pool();
    let b = mk_pool();

    // identical gen-1 traffic establishes identical lineages
    let a1 = a.handle(0).predict_final(snap1.clone(), theta.clone(), xq1.clone()).unwrap();
    let b1 = b.handle(0).predict_final(snap1.clone(), theta.clone(), xq1.clone()).unwrap();
    assert_eq!(a1[0].0.to_bits(), b1[0].0.to_bits());
    assert_eq!(a1[0].1.to_bits(), b1[0].1.to_bits());

    // pool A ingests the new epoch via Observe, pool B never hears of it
    let report = a.handle(0).observe(snap2.clone(), theta.clone()).unwrap();
    assert_eq!(report.generation, snap2.generation);
    assert!(report.mvm_rows > 0, "warm re-solve applies at least one residual MVM");
    assert_eq!(a.stats(0).observes.load(Ordering::Relaxed), 1);

    // gen-2 queries: A rides the Observe-refreshed lineage, B solves from
    // scratch (warm-started off its own gen-1 lineage) — same bits required
    let a2 = a.handle(0).predict_final(snap2.clone(), theta.clone(), xq2.clone()).unwrap();
    let b2 = b.handle(0).predict_final(snap2.clone(), theta.clone(), xq2.clone()).unwrap();
    assert_eq!(
        a2[0].0.to_bits(),
        b2[0].0.to_bits(),
        "observe-then-query mean diverged from from-scratch"
    );
    assert_eq!(
        a2[0].1.to_bits(),
        b2[0].1.to_bits(),
        "observe-then-query variance diverged from from-scratch"
    );
}

/// Adversarial-mask ingestion: a task whose dataset carries a fully-masked
/// row through every generation AND a row that un-masks for the first time
/// in generation 2 must observe + serve finite answers that match the
/// dense `gp::naive` oracle on the extended mask.
#[test]
fn observe_handles_fully_masked_and_freshly_unmasked_rows() {
    use lkgp::gp::naive;
    let (n, m, d) = (6usize, 5usize, 2usize);
    let mut rng = Pcg64::new(77);
    let x = Matrix::from_vec(n, d, rng.uniform_vec(n * d, 0.0, 1.0));
    let t: Vec<f64> = (0..m).map(|j| j as f64 / (m - 1) as f64).collect();
    let raw = |i: usize, j: usize| -0.5 + 0.1 * j as f64 + 0.02 * i as f64;
    // gen 1: row 3 freshly registered (fully masked), row 5 fully masked
    // for good; everyone else observes a 2-epoch prefix
    let mut y1 = Matrix::zeros(n, m);
    let mut mask1 = Matrix::zeros(n, m);
    for i in 0..n {
        if i == 3 || i == 5 {
            continue;
        }
        for j in 0..2 {
            mask1[(i, j)] = 1.0;
            y1[(i, j)] = raw(i, j);
        }
    }
    // gen 2: one more epoch everywhere, and row 3 un-masks its first epoch
    let mut y2 = y1.clone();
    let mut mask2 = mask1.clone();
    for i in 0..n {
        if i == 5 {
            continue; // still never observed
        }
        let j = if i == 3 { 0 } else { 2 };
        mask2[(i, j)] = 1.0;
        y2[(i, j)] = raw(i, j);
    }
    let ids: Vec<TrialId> = (0..n).map(TrialId).collect();
    let snap_of = |generation: u64, y: &Matrix, mask: &Matrix| Snapshot {
        generation,
        data: Arc::new(Dataset {
            x: x.clone(),
            t: t.clone(),
            y: y.clone(),
            mask: mask.clone(),
        }),
        row_ids: Arc::new(ids.clone()),
        all_x: Arc::new(x.clone()),
        all_ids: Arc::new(ids.clone()),
        ytf: Arc::new(YTransform { max: 0.0, std: 1.0 }),
        warm: None,
    };
    let snap1 = snap_of(1, &y1, &mask1);
    let snap2 = snap_of(2, &y2, &mask2);
    let theta = Theta::default_packed(d);
    let xq = Matrix::from_vec(1, d, vec![0.4, 0.6]);

    let pool = ServicePool::spawn(
        rust_engines(1),
        PoolCfg { workers: 1, warm_start: true, max_replicas: 0, ..Default::default() },
    );
    let handle = pool.handle(0);
    // gen 1 lineage, then ingest the adversarial gen-2 mask via Observe
    handle.observe(snap1, theta.clone()).unwrap();
    let report = handle.observe(snap2.clone(), theta.clone()).unwrap();
    assert_eq!(report.generation, 2);
    let got = handle.predict_final(snap2.clone(), theta.clone(), xq.clone()).unwrap();

    // dense oracle on the same extended mask (identity YTransform keeps
    // both sides in the same units)
    let want = naive::predict_final_exact(&theta, &snap2.data, &xq).unwrap();
    assert!(got[0].0.is_finite() && got[0].1 > 0.0);
    assert!(
        (got[0].0 - want[0].0).abs() < 1e-6,
        "observe-path mean {} vs dense oracle {}",
        got[0].0,
        want[0].0
    );
    assert!(
        (got[0].1 - want[0].1).abs() < 1e-6,
        "observe-path variance {} vs dense oracle {}",
        got[0].1,
        want[0].1
    );
}

/// Hash-bucketed routing is deterministic across pool restarts (same task
/// -> same bucket), folds every task into the configured bucket range,
/// and stays behavior-preserving: bucket-mates answer bit-identically to
/// a 1:1 pool serving the same requests.
#[test]
fn bucket_routing_is_deterministic_and_behavior_preserving() {
    use lkgp::coordinator::EngineFactory;
    use lkgp::lcbench::corpus::SimCorpus;
    let tasks = 40usize;
    let corpus = SimCorpus::new(tasks, 4, 5);
    let mk = || {
        let factory: EngineFactory = Box::new(|_| Box::<RustEngine>::default());
        ServicePool::from_corpus(
            &corpus,
            factory,
            PoolCfg { workers: 2, warm_start: false, buckets: 4, ..Default::default() },
        )
    };
    let pool = mk();
    assert_eq!(pool.shards(), tasks, "all tasks stay addressable");
    assert_eq!(pool.buckets(), 4);
    let route: Vec<usize> = (0..tasks).map(|t| pool.bucket_of(t)).collect();
    assert!(route.iter().all(|&b| b < 4));
    assert!(
        (0..4).all(|b| route.contains(&b)),
        "40 tasks over 4 buckets should touch every bucket: {route:?}"
    );
    // restart: a second pool over the same corpus routes identically
    let pool2 = mk();
    let route2: Vec<usize> = (0..tasks).map(|t| pool2.bucket_of(t)).collect();
    assert_eq!(route, route2, "routing must be deterministic across restarts");

    // behavior preservation: two bucket-mates served through the folded
    // pool answer bit-identically to a 1:1 pool (cold solves)
    let (ta, tb) = {
        let a = 0usize;
        let b = (1..tasks).find(|&t| route[t] == route[a]).expect("40 tasks, 4 buckets");
        (a, b)
    };
    let snap_a = snapshot_for(Preset::FashionMnist, 8, 3);
    let snap_b = snapshot_for(Preset::Higgs, 8, 4);
    let theta = Theta::default_packed(7);
    let xq = Matrix::from_vec(1, 7, snap_a.all_x.row(0).to_vec());
    let flat = ServicePool::spawn(
        rust_engines(2),
        PoolCfg { workers: 2, warm_start: false, ..Default::default() },
    );
    for (task, flat_task, snap) in [(ta, 0usize, &snap_a), (tb, 1usize, &snap_b)] {
        let got = pool
            .handle(task)
            .predict_final(snap.clone(), theta.clone(), xq.clone())
            .unwrap();
        let want = flat
            .handle(flat_task)
            .predict_final(snap.clone(), theta.clone(), xq.clone())
            .unwrap();
        assert_eq!(got[0].0.to_bits(), want[0].0.to_bits(), "task {task} mean diverged");
        assert_eq!(got[0].1.to_bits(), want[0].1.to_bits(), "task {task} variance diverged");
    }
}

/// The generation fence is per-task, not per-bucket: an `Observe` write
/// for one task must retire in-flight replica reads of THAT task's older
/// generations (replicas never serve a pre-Observe generation), while a
/// bucket-mate's concurrent reads sail through unretired.
#[test]
fn observe_fence_is_per_task_inside_a_bucket() {
    use lkgp::coordinator::EngineFactory;
    use lkgp::lcbench::corpus::SimCorpus;
    use std::sync::Mutex;

    // two tasks folded onto ONE bucket, backed by a gated engine so the
    // writer can be pinned mid-refit while replicas serve reads
    let corpus = SimCorpus::new(2, 4, 9);
    let (gate, engine) = GatedEngine::pair();
    let stash = Mutex::new(Some(engine));
    let factory: EngineFactory =
        Box::new(move |_| stash.lock().unwrap().take().expect("one bucket, one engine"));
    let pool = ServicePool::from_corpus(
        &corpus,
        factory,
        PoolCfg { workers: 3, warm_start: true, buckets: 1, max_replicas: 2, ..Default::default() },
    );
    assert_eq!(pool.bucket_of(0), pool.bucket_of(1), "both tasks share the bucket");

    // task 0's curve store drives two generations; task 1 stays at gen 1
    let mut rng = Pcg64::new(9);
    let task0 = Task::generate(Preset::Higgs, 16, &mut rng);
    let mut reg = Registry::new();
    for i in 0..task0.n() {
        let id = reg.add(task0.configs.row(i).to_vec());
        for j in 0..4 {
            reg.observe(id, task0.curves[(i, j)], task0.m()).unwrap();
        }
    }
    let mut store = CurveStore::new(task0.m());
    let snap0_g1 = store.snapshot(&reg).unwrap();
    for i in 0..task0.n() {
        reg.observe(TrialId(i), task0.curves[(i, 4)], task0.m()).unwrap();
    }
    let snap0_g2 = store.snapshot(&reg).unwrap();
    let snap1 = snapshot_for(Preset::FashionMnist, 10, 23);
    let theta = Theta::default_packed(7);
    let xq0 = Matrix::from_vec(1, 7, snap0_g1.all_x.row(0).to_vec());
    let xq1 = Matrix::from_vec(1, 7, snap1.all_x.row(0).to_vec());

    // lineages for both tasks at gen 1 (writer solves, replicas reuse)
    pool.handle(0)
        .query(snap0_g1.clone(), theta.clone(), vec![Query::MeanAtFinal { xq: xq0.clone() }])
        .unwrap();
    pool.handle(1)
        .query(snap1.clone(), theta.clone(), vec![Query::MeanAtFinal { xq: xq1.clone() }])
        .unwrap();

    // pin the writer on task 0's gated refit, then float two heavy reads:
    // task 0 @ gen 1 (will be fenced off by the Observe) and the
    // bucket-mate task 1 @ its own gen 1 (must NOT be)
    let frx = pin_writer(&pool, &snap0_g1, &theta);
    let (r0tx, r0rx) = mpsc::channel();
    pool.submit(
        0,
        Request::Query {
            snapshot: snap0_g1.clone(),
            theta: theta.clone(),
            queries: vec![
                Query::CurveSamples { xq: xq0.clone(), n: 128, seed: 5 },
                Query::MeanAtFinal { xq: xq0.clone() },
            ],
            resp: r0tx,
        },
    )
    .unwrap();
    let (r1tx, r1rx) = mpsc::channel();
    pool.submit(
        1,
        Request::Query {
            snapshot: snap1.clone(),
            theta: theta.clone(),
            queries: vec![
                Query::CurveSamples { xq: xq1.clone(), n: 128, seed: 6 },
                Query::MeanAtFinal { xq: xq1.clone() },
            ],
            resp: r1tx,
        },
    )
    .unwrap();
    // wait until replicas stole both reads (writer is pinned, so only
    // replicas can empty the bucket queue) ...
    while pool.queue_depth(0) > 0 {
        std::thread::yield_now();
    }
    // ... then advance task 0's fence with an Observe write (gen 2)
    let (otx, orx) = mpsc::channel();
    pool.submit(
        0,
        Request::Observe { snapshot: snap0_g2.clone(), theta: theta.clone(), resp: otx },
    )
    .unwrap();
    // release the pinned refit; the writer then drains the Observe and
    // any retired reads
    gate.send(()).unwrap();

    let a0 = r0rx
        .recv_timeout(Duration::from_secs(120))
        .expect("fenced task-0 read must still be answered (by the writer)")
        .unwrap();
    let a1 = r1rx
        .recv_timeout(Duration::from_secs(120))
        .expect("bucket-mate read must be served")
        .unwrap();
    assert_eq!(a0.len(), 2);
    assert_eq!(a1.len(), 2);
    let report = orx.recv_timeout(Duration::from_secs(120)).unwrap().unwrap();
    assert_eq!(report.generation, snap0_g2.generation);
    // the Observe write fenced task 0's stale read off the replica path;
    // with per-task fencing the bucket-mate's read never retires, so at
    // most that one retire is ever recorded
    let retires = pool.stats(0).stale_replica_retires.load(Ordering::Relaxed);
    assert!(
        retires <= 1,
        "task 1's read must not retire on task 0's fence (saw {retires})"
    );
    frx.recv().unwrap().unwrap();
}
