//! Determinism property tests for the data-parallel compute core.
//!
//! The worker team's contract (docs/parallelism.md): work is split into
//! parts keyed by the *logical* thread count, and each part's arithmetic
//! is independent of which lane executes it — so on the f64 path, every
//! result is bit-identical for every thread count, on adversarial masks
//! included. The mixed-precision (f32-storage) path gets tolerance-based
//! parity against the f64 oracle instead, with iterative refinement
//! recovering f64-grade residuals. `ci.sh`'s `par` gate adds the
//! cross-process `LKGP_THREADS=1` vs `=N` check on top of these
//! in-process pinned-thread-count properties.

use lkgp::gp::kernels;
use lkgp::gp::{MaskedKronOp, MaskedKronOpF32, Theta};
use lkgp::linalg::{pcg_batch_warm, refined_solve, LinOp, Matrix};
use lkgp::rng::Pcg64;

/// Adversarial observation masks: full, empty, single live row, ragged
/// early-stopping prefixes, random holes, and a checkerboard (worst case
/// for the masked epilogue's branch behavior).
fn adversarial_masks(n: usize, m: usize, seed: u64) -> Vec<(&'static str, Matrix)> {
    let mut rng = Pcg64::new(seed);
    let mut masks = Vec::new();
    masks.push(("full", Matrix::from_fn(n, m, |_, _| 1.0)));
    masks.push(("empty", Matrix::zeros(n, m)));
    masks.push((
        "single-row",
        Matrix::from_fn(n, m, |i, _| if i == n / 2 { 1.0 } else { 0.0 }),
    ));
    let mut ragged = Matrix::zeros(n, m);
    for i in 0..n {
        let len = 1 + (i * 7) % m;
        for j in 0..len {
            ragged[(i, j)] = 1.0;
        }
    }
    masks.push(("ragged-prefix", ragged));
    masks.push((
        "random",
        Matrix::from_fn(n, m, |_, _| if rng.uniform() < 0.6 { 1.0 } else { 0.0 }),
    ));
    masks.push((
        "checkerboard",
        Matrix::from_fn(n, m, |i, j| ((i + j) % 2) as f64),
    ));
    masks
}

fn toy_factors(n: usize, m: usize, seed: u64) -> (Matrix, Matrix) {
    let mut rng = Pcg64::new(seed);
    let theta = Theta::default_packed(3);
    let th = Theta::unpack(&theta);
    let x = Matrix::from_vec(n, 3, rng.uniform_vec(n * 3, 0.0, 1.0));
    let t: Vec<f64> = (0..m).map(|j| j as f64 / (m - 1).max(1) as f64).collect();
    let k1 = kernels::rbf(&x, &x, &th.lengthscales);
    let k2 = kernels::matern12(&t, &t, th.t_lengthscale, th.outputscale);
    (k1, k2)
}

/// `LinOp` adapter that pins the operator's worker-thread count, so one
/// process can drive a full PCG solve through different simulated team
/// widths and compare bitwise.
struct PinnedOp<'a> {
    op: &'a MaskedKronOp<'a>,
    threads: usize,
}

impl LinOp for PinnedOp<'_> {
    fn len(&self) -> usize {
        self.op.len()
    }

    fn apply_batch(&self, x: &[f64], out: &mut [f64], batch: usize) {
        self.op.apply_batch_with_threads(x, out, batch, self.threads);
    }
}

#[test]
fn f64_mvm_bit_identical_across_thread_counts_on_adversarial_masks() {
    let (n, m) = (13, 9);
    let (k1, k2) = toy_factors(n, m, 5);
    let mut rng = Pcg64::new(6);
    for (name, mask) in adversarial_masks(n, m, 7) {
        let op = MaskedKronOp::new(&k1, &k2, &mask, 1e-2);
        let batch = 5;
        let x = rng.normal_vec(batch * n * m);
        let mut base = vec![0.0; batch * n * m];
        op.apply_batch_with_threads(&x, &mut base, batch, 1);
        for threads in [2, 3, 8, 64] {
            let mut got = vec![0.0; batch * n * m];
            op.apply_batch_with_threads(&x, &mut got, batch, threads);
            for (i, (a, b)) in got.iter().zip(&base).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "mask={name} threads={threads} idx={i}: {a} vs {b}"
                );
            }
        }
    }
}

#[test]
fn f64_pcg_solve_bit_identical_across_thread_counts() {
    let (n, m) = (11, 8);
    let (k1, k2) = toy_factors(n, m, 15);
    let mut rng = Pcg64::new(16);
    for (name, mask) in adversarial_masks(n, m, 17) {
        let op = MaskedKronOp::new(&k1, &k2, &mask, 1e-2);
        let batch = 3;
        let b = rng.normal_vec(batch * n * m);
        let pinned1 = PinnedOp { op: &op, threads: 1 };
        let (x1, s1) = pcg_batch_warm(&pinned1, &b, None, None, 1e-10, 2000);
        assert!(s1.converged, "mask={name} must converge");
        for threads in [2, 8] {
            let pinned = PinnedOp { op: &op, threads };
            let (xt, st) = pcg_batch_warm(&pinned, &b, None, None, 1e-10, 2000);
            assert_eq!(s1.iters, st.iters, "mask={name} threads={threads}");
            for (i, (a, c)) in xt.iter().zip(&x1).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    c.to_bits(),
                    "mask={name} threads={threads} idx={i}"
                );
            }
        }
    }
}

#[test]
fn f32_mvm_within_tolerance_and_thread_invariant() {
    let (n, m) = (12, 7);
    let (k1, k2) = toy_factors(n, m, 25);
    let mut rng = Pcg64::new(26);
    for (name, mask) in adversarial_masks(n, m, 27) {
        let op = MaskedKronOp::new(&k1, &k2, &mask, 1e-2);
        let fast = MaskedKronOpF32::from_op(&op);
        let batch = 4;
        let x = rng.normal_vec(batch * n * m);
        let mut exact = vec![0.0; batch * n * m];
        let mut got = vec![0.0; batch * n * m];
        op.apply_batch_with_threads(&x, &mut exact, batch, 1);
        fast.apply_batch_with_threads(&x, &mut got, batch, 1);
        let scale = exact.iter().fold(1.0f64, |a, &v| a.max(v.abs()));
        for (i, (a, b)) in got.iter().zip(&exact).enumerate() {
            assert!(
                (a - b).abs() < 1e-4 * scale,
                "mask={name} idx={i}: f32 MVM drifted {a} vs {b}"
            );
        }
        // the f32 path obeys the same thread-count determinism contract
        for threads in [2, 8] {
            let mut gt = vec![0.0; batch * n * m];
            fast.apply_batch_with_threads(&x, &mut gt, batch, threads);
            for (i, (a, b)) in gt.iter().zip(&got).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "mask={name} threads={threads} idx={i}");
            }
        }
    }
}

#[test]
fn refined_f32_solve_matches_f64_oracle_within_tolerance() {
    let (n, m) = (10, 8);
    let (k1, k2) = toy_factors(n, m, 35);
    let mut rng = Pcg64::new(36);
    for (name, mask) in adversarial_masks(n, m, 37) {
        let op = MaskedKronOp::new(&k1, &k2, &mask, 1e-2);
        let fast = MaskedKronOpF32::from_op(&op);
        let batch = 2;
        let b = rng.normal_vec(batch * n * m);
        let (oracle, os) = pcg_batch_warm(&op, &b, None, None, 1e-12, 4000);
        assert!(os.converged, "mask={name} oracle must converge");
        let (x, rs) = refined_solve(&op, &fast, &b, None, None, 1e-9, 1e-4, 12, 2000);
        assert!(rs.converged, "mask={name} refinement must converge: {:?}", rs.rel_residual);
        let scale = oracle.iter().fold(1.0f64, |a, &v| a.max(v.abs()));
        for (i, (a, c)) in x.iter().zip(&oracle).enumerate() {
            assert!(
                (a - c).abs() < 1e-6 * scale,
                "mask={name} idx={i}: refined {a} vs oracle {c}"
            );
        }
    }
}
