//! Preconditioned-CG correctness: a preconditioner must never change
//! *what* the solver converges to, only how fast it gets there — and the
//! identity preconditioner must not change anything at all.

use lkgp::gp::kernels;
use lkgp::gp::operator::{dense_masked_kron, MaskedKronOp};
use lkgp::gp::{PrecondCfg, PrecondFactors, Theta};
use lkgp::lcbench::toy_dataset;
use lkgp::linalg::pcg::{pcg_batch_warm, IdentityPrecond};
use lkgp::linalg::{cg_batch_warm, pivoted_cholesky, LinOp, Matrix};
use lkgp::rng::Pcg64;

/// Random kernel pair for an (n, m) grid.
fn gen_kernels(rng: &mut Pcg64, n: usize, m: usize, d: usize) -> (Matrix, Matrix) {
    let x = Matrix::from_vec(n, d, rng.uniform_vec(n * d, 0.0, 1.0));
    let ls: Vec<f64> = (0..d).map(|_| rng.uniform_in(0.5, 2.0)).collect();
    let k1 = kernels::rbf(&x, &x, &ls);
    let t: Vec<f64> = (0..m).map(|i| i as f64 / m as f64).collect();
    let k2 = kernels::matern12(&t, &t, rng.uniform_in(0.2, 1.0), rng.uniform_in(0.5, 2.0));
    (k1, k2)
}

/// The four adversarial mask families (mirrors tests/props.rs): all-zero
/// rows, all-zero columns, a single observed entry, full mask.
fn gen_adversarial_mask(rng: &mut Pcg64, n: usize, m: usize, variant: usize) -> Matrix {
    match variant {
        0 => {
            let mut mk = Matrix::from_fn(n, m, |_, _| if rng.uniform() < 0.6 { 1.0 } else { 0.0 });
            for i in 0..n {
                if rng.uniform() < 0.5 {
                    for j in 0..m {
                        mk[(i, j)] = 0.0;
                    }
                }
            }
            mk
        }
        1 => {
            let dead: Vec<bool> = (0..m).map(|_| rng.uniform() < 0.5).collect();
            Matrix::from_fn(n, m, |_, j| if dead[j] { 0.0 } else { 1.0 })
        }
        2 => {
            let (ri, cj) = (rng.below(n), rng.below(m));
            Matrix::from_fn(n, m, |i, j| if i == ri && j == cj { 1.0 } else { 0.0 })
        }
        _ => Matrix::from_fn(n, m, |_, _| 1.0),
    }
}

#[test]
fn identity_precond_is_bit_exact_with_cg_on_masked_kron() {
    let mut rng = Pcg64::new(1);
    let (n, m) = (9, 7);
    let (k1, k2) = gen_kernels(&mut rng, n, m, 2);
    let mask = gen_adversarial_mask(&mut rng, n, m, 0);
    let op = MaskedKronOp::new(&k1, &k2, &mask, 0.15);
    let nm = n * m;
    let batch = 4;
    let b = rng.normal_vec(batch * nm);
    let guess = rng.normal_vec(batch * nm);
    for x0 in [None, Some(&guess[..])] {
        let (cg_x, cg_s) = cg_batch_warm(&op, &b, x0, 1e-9, 2000);
        let (pcg_x, pcg_s) = pcg_batch_warm(&op, &b, x0, Some(&IdentityPrecond), 1e-9, 2000);
        assert_eq!(cg_x, pcg_x, "warm={}", x0.is_some());
        assert_eq!(cg_s.iters, pcg_s.iters);
        assert_eq!(cg_s.iters_per_rhs, pcg_s.iters_per_rhs);
        assert_eq!(cg_s.rel_residual, pcg_s.rel_residual);
        assert_eq!(cg_s.mvms, pcg_s.mvms);
        assert_eq!(cg_s.mvm_rows, pcg_s.mvm_rows);
    }
}

#[test]
fn pcg_matches_cg_solutions_under_adversarial_masks() {
    for seed in 0..6u64 {
        let mut rng = Pcg64::new(100 + seed);
        let n = 4 + rng.below(6);
        let m = 3 + rng.below(6);
        let (k1, k2) = gen_kernels(&mut rng, n, m, 2);
        let s2 = rng.uniform_in(0.05, 0.5);
        for variant in 0..4 {
            let mask = gen_adversarial_mask(&mut rng, n, m, variant);
            let op = MaskedKronOp::new(&k1, &k2, &mask, s2);
            let packed = Theta::default_packed(2);
            let factors = PrecondFactors::build(PrecondCfg::Auto, &k1, &k2, &mask, &packed);
            let rhs: Vec<f64> = mask.data().iter().map(|&mk| mk * rng.normal()).collect();
            if rhs.iter().all(|&v| v == 0.0) {
                continue; // fully-unobserved grid: nothing to solve
            }
            let (plain, ps) = op.solve(&rhs, 1e-10, 5000);
            let (pcgx, ss) = op.solve_precond(&rhs, None, factors.as_ref(), 1e-10, 5000);
            assert!(ps.converged, "variant={variant} plain");
            assert!(ss.converged, "variant={variant} pcg");
            for i in 0..n * m {
                assert!(
                    (plain[i] - pcgx[i]).abs() < 1e-6,
                    "variant={variant} i={i}: {} vs {}",
                    plain[i],
                    pcgx[i]
                );
                if mask.data()[i] == 0.0 {
                    assert_eq!(pcgx[i], 0.0, "variant={variant} off-mask leak");
                }
            }
        }
    }
}

#[test]
fn precond_apply_matches_dense_solve_oracle() {
    // Masked preconditioner == blockdiag(dense (K̃+σ²I)⁻¹ on the observed
    // block via its own mask-embedded definition, 1/σ² elsewhere). Checked
    // for the observed-Gram strategy at full rank, where the observed
    // block is EXACTLY (K_obs + σ²I)⁻¹.
    let mut rng = Pcg64::new(7);
    let (n, m) = (6, 5);
    let (k1, k2) = gen_kernels(&mut rng, n, m, 2);
    let mask = gen_adversarial_mask(&mut rng, n, m, 0);
    let s2 = 0.3;
    let packed = Theta::default_packed(2);
    let n_obs = mask.data().iter().filter(|&&mv| mv > 0.0).count();
    if n_obs == 0 {
        return;
    }
    let factors =
        PrecondFactors::build(PrecondCfg::Rank(n_obs), &k1, &k2, &mask, &packed).unwrap();
    let pc = factors.apply_state(&mask, s2);
    use lkgp::linalg::pcg::Preconditioner;
    let v = rng.normal_vec(n * m);
    let mut z = vec![0.0; n * m];
    pc.apply_batch(&v, &mut z, 1);

    let dense = dense_masked_kron(&k1, &k2, &mask, s2);
    let idx: Vec<usize> = mask
        .data()
        .iter()
        .enumerate()
        .filter(|(_, &mv)| mv > 0.0)
        .map(|(i, _)| i)
        .collect();
    let mut proj = Matrix::zeros(n_obs, n_obs);
    for (a, &ia) in idx.iter().enumerate() {
        for (b, &ib) in idx.iter().enumerate() {
            proj[(a, b)] = dense[(ia, ib)];
        }
    }
    let l = lkgp::linalg::cholesky(&proj).unwrap();
    let vobs: Vec<f64> = idx.iter().map(|&i| v[i]).collect();
    let want = lkgp::linalg::chol_solve(&l, &vobs);
    for (a, &ia) in idx.iter().enumerate() {
        assert!((z[ia] - want[a]).abs() < 1e-7, "obs {a}");
    }
    for (i, &mk) in mask.data().iter().enumerate() {
        if mk == 0.0 {
            assert!((z[i] - v[i] / s2).abs() < 1e-12, "miss {i}");
        }
    }
}

#[test]
fn pivoted_cholesky_rank_ladder_on_kernel_matrix() {
    // Kernel Gram matrices are the production input: approximation error
    // must fall monotonically with rank and vanish at full rank.
    let mut rng = Pcg64::new(11);
    let n = 20;
    let x = Matrix::from_vec(n, 3, rng.uniform_vec(n * 3, 0.0, 1.0));
    let k1 = kernels::rbf(&x, &x, &[1.0, 1.0, 1.0]);
    let mut prev = f64::INFINITY;
    for r in [1, 2, 4, 8, 16, n] {
        let pc = pivoted_cholesky(&k1, r, 0.0);
        let rec = pc.l.matmul(&pc.l.transpose());
        let err = k1.max_abs_diff(&rec);
        assert!(err <= prev + 1e-9, "rank {r}: {err} > {prev}");
        prev = err;
    }
    assert!(prev < 1e-7, "full rank not exact: {prev}");
}

#[test]
fn preconditioned_engine_parity_and_full_loop() {
    use lkgp::runtime::{Engine, RustEngine};
    let data = toy_dataset(10, 12, 3, 15);
    let theta = Theta::default_packed(3);
    let xq = Matrix::from_vec(2, 3, vec![0.2, 0.4, 0.6, 0.8, 0.1, 0.3]);

    // same theta, tight tolerance: plain and preconditioned engines agree
    let mut plain_eng = RustEngine::default();
    plain_eng.cfg.cg_tol = 1e-8;
    let mut pcg_eng = RustEngine::default();
    pcg_eng.cfg.cg_tol = 1e-8;
    pcg_eng.cfg.precond = PrecondCfg::Auto;
    let a = plain_eng.predict_final(&theta, &data, &xq).unwrap();
    let b = pcg_eng.predict_final(&theta, &data, &xq).unwrap();
    for (pa, pb) in a.iter().zip(&b) {
        assert!(
            (pa.0 - pb.0).abs() < 1e-5 && (pa.1 - pb.1).abs() < 1e-5,
            "{pa:?} vs {pb:?}"
        );
    }

    // the full fit/predict/sample loop runs and improves the exact MAP
    // objective with preconditioning on
    let before = lkgp::gp::lkgp::mll_exact(&theta, &data).unwrap();
    let mut eng = RustEngine::default();
    eng.cfg.precond = PrecondCfg::Auto;
    let fitted = eng.fit(&theta, &data, 2).unwrap();
    let after = lkgp::gp::lkgp::mll_exact(&fitted, &data).unwrap();
    assert!(after > before, "{before} -> {after}");
    let preds = eng.predict_final(&fitted, &data, &xq).unwrap();
    for (mu, var) in preds {
        assert!(mu.is_finite() && var > 0.0);
    }
    let samples = eng.sample_curves(&fitted, &data, &xq, 4, 3).unwrap();
    assert_eq!(samples.len(), 4);
}

#[test]
fn preconditioned_warm_predict_reports_factors_and_fewer_rows() {
    use lkgp::runtime::{Engine, RustEngine};
    let data = toy_dataset(12, 14, 3, 17);
    let theta = Theta::default_packed(3);
    let xq = Matrix::from_vec(2, 3, vec![0.3, 0.5, 0.7, 0.6, 0.2, 0.9]);

    let mut eng = RustEngine::default();
    eng.cfg.precond = PrecondCfg::Auto;
    eng.cfg.cg_tol = 1e-6;
    let cold = eng
        .predict_final_cached(&theta, &data, &xq, None, None)
        .unwrap();
    let factors = cold.precond.clone().expect("factors reported");
    assert!(cold.cg_mvm_rows > 0);

    // second call: cached factors + the full converged solve buffer as the
    // guess -> no more work than the cold pass (the strict at-scale claim
    // is gated by BENCH_pcg.json)
    let mut guess = cold.alpha.clone().unwrap();
    guess.extend_from_slice(cold.cross.as_ref().unwrap());
    let warm = eng
        .predict_final_cached(&theta, &data, &xq, Some(&guess), Some(factors.clone()))
        .unwrap();
    assert!(
        warm.cg_mvm_rows <= cold.cg_mvm_rows,
        "warm {} vs cold {}",
        warm.cg_mvm_rows,
        cold.cg_mvm_rows
    );
    assert!(warm.cg_iters <= cold.cg_iters);
    // the factors round-trip unchanged (mask and theta identical)
    let reused = warm.precond.expect("factors still reported");
    assert!(std::sync::Arc::ptr_eq(&factors, &reused), "factors rebuilt");
    for (a, b) in warm.preds.iter().zip(&cold.preds) {
        assert!((a.0 - b.0).abs() < 0.05 && (a.1 - b.1).abs() < 0.05);
    }
}

#[test]
fn pool_serves_with_preconditioning_on() {
    use lkgp::coordinator::{CurveStore, PoolCfg, Registry, ServicePool};
    use lkgp::runtime::{Engine, RustEngine};

    let mut reg = Registry::new();
    for i in 0..6 {
        let id = reg.add(vec![i as f64 * 0.1, 0.5 - i as f64 * 0.05]);
        for j in 0..3 + i % 3 {
            reg.observe(id, 0.4 + 0.05 * j as f64 + 0.01 * i as f64, 8).unwrap();
        }
    }
    let snap = CurveStore::new(8).snapshot(&reg).unwrap();

    let engines: Vec<Box<dyn Engine>> = (0..1)
        .map(|_| {
            let mut eng = RustEngine::default();
            eng.cfg.precond = PrecondCfg::Auto;
            Box::new(eng) as Box<dyn Engine>
        })
        .collect();
    let pool = ServicePool::spawn(engines, PoolCfg { workers: 1, ..Default::default() });
    let handle = pool.handle(0);
    let theta = Theta::default_packed(2);
    let xq = Matrix::from_vec(1, 2, vec![0.4, 0.4]);
    use lkgp::coordinator::PredictClient;
    let a = handle
        .predict_final(snap.clone(), theta.clone(), xq.clone())
        .unwrap();
    // second call hits the warm cache (alpha + factors from the lineage)
    let b = handle.predict_final(snap, theta, xq).unwrap();
    assert_eq!(
        pool.stats(0)
            .warm_hits
            .load(std::sync::atomic::Ordering::Relaxed),
        1
    );
    for (x, y) in a.iter().zip(&b) {
        assert!((x.0 - y.0).abs() < 1e-6 && (x.1 - y.1).abs() < 1e-6);
        assert!(x.0.is_finite() && x.1 > 0.0);
    }
}

#[test]
fn mask_compaction_visible_through_operator_stats() {
    // A batch where one RHS is pre-converged: mvm_rows must charge the
    // frozen system only for the warm residual apply.
    let mut rng = Pcg64::new(21);
    let (n, m) = (10, 8);
    let (k1, k2) = gen_kernels(&mut rng, n, m, 2);
    let mask = gen_adversarial_mask(&mut rng, n, m, 3);
    let op = MaskedKronOp::new(&k1, &k2, &mask, 0.2);
    let nm = n * m;
    let b1 = rng.normal_vec(nm);
    let (x1, _) = op.solve(&b1, 1e-12, 4000);
    let mut b = vec![0.0; 2 * nm];
    b[..nm].copy_from_slice(&b1);
    b[nm..].copy_from_slice(&rng.normal_vec(nm));
    let mut guess = vec![0.0; 2 * nm];
    guess[..nm].copy_from_slice(&x1);
    let (_, stats) = op.solve_warm(&b, Some(&guess), 1e-8, 4000);
    assert_eq!(
        stats.mvm_rows,
        2 + stats.iters_per_rhs.iter().sum::<usize>(),
        "stats={stats:?}"
    );
    assert!(stats.iters_per_rhs[0] <= 1);
    assert!(op.len() == nm);
}
