//! Session-API acceptance: every deprecated free-function shim is a
//! bit-exact thin wrapper over the session objects, `Variance`/`Quantiles`
//! agree with the dense Cholesky oracle, and typed query batches share one
//! underlying solve end-to-end through the `ServicePool`.
#![allow(deprecated)] // the parity tests exercise the deprecated shims on purpose

use std::sync::atomic::Ordering;
use std::sync::Arc;

use lkgp::coordinator::{CurveStore, PoolCfg, PredictClient, Registry, ServicePool, Snapshot};
use lkgp::gp::lkgp as lkgp_fns;
use lkgp::gp::lkgp::{Dataset, SolverCfg};
use lkgp::gp::session::{normal_quantile, Answer, FitSession, Posterior, Query};
use lkgp::gp::{naive, PrecondCfg, Theta};
use lkgp::linalg::Matrix;
use lkgp::rng::Pcg64;
use lkgp::runtime::{Engine, RustEngine};

/// Adversarial masks: fully observed, single-entry, prefix, gapped,
/// fully-masked (padding) and final-entry-only rows, all in one dataset.
fn adversarial_dataset(seed: u64) -> Dataset {
    let (n, m, d) = (7usize, 6usize, 2usize);
    let mut rng = Pcg64::new(seed);
    let x = Matrix::from_vec(n, d, rng.uniform_vec(n * d, 0.0, 1.0));
    let t: Vec<f64> = (0..m).map(|i| i as f64 / (m - 1) as f64).collect();
    let mut mask = Matrix::zeros(n, m);
    for j in 0..m {
        mask[(0, j)] = 1.0; // fully observed
    }
    mask[(1, 0)] = 1.0; // single entry
    for j in 0..3 {
        mask[(2, j)] = 1.0; // prefix
    }
    mask[(3, 0)] = 1.0;
    mask[(3, 2)] = 1.0;
    mask[(3, 4)] = 1.0; // gaps
    // row 4 stays fully masked (padding row — the operator must treat it
    // as inert)
    for j in 0..5 {
        mask[(5, j)] = 1.0;
    }
    mask[(6, m - 1)] = 1.0; // final entry only
    let mut y = Matrix::zeros(n, m);
    for i in 0..n {
        for j in 0..m {
            if mask[(i, j)] > 0.0 {
                y[(i, j)] = -0.6 + 0.1 * j as f64 + 0.05 * rng.normal();
            }
        }
    }
    Dataset { x, t, y, mask }
}

fn assert_bits_eq(a: &[f64], b: &[f64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: entry {i}: {x} vs {y}");
    }
}

#[test]
fn predict_final_shims_are_bit_exact_with_session() {
    for seed in [1u64, 2, 3] {
        let data = adversarial_dataset(seed);
        let packed = Theta::default_packed(2);
        let mut rng = Pcg64::new(100 + seed);
        let xq = Matrix::from_vec(3, 2, rng.uniform_vec(6, 0.0, 1.0));
        for precond in [PrecondCfg::Off, PrecondCfg::Auto] {
            let cfg = SolverCfg { precond, ..Default::default() };
            let (shim_preds, shim_solves, shim_cg) =
                lkgp_fns::predict_final_warm(&packed, &data, &xq, &cfg, None).unwrap();
            let mut post =
                Posterior::new(Arc::new(data.clone()), packed.clone(), cfg.clone());
            let preds = match post.answer(&Query::MeanAtFinal { xq: xq.clone() }).unwrap() {
                Answer::Final(v) => v,
                other => panic!("want Final, got {other:?}"),
            };
            let flat_shim: Vec<f64> =
                shim_preds.iter().flat_map(|p| [p.0, p.1]).collect();
            let flat_post: Vec<f64> = preds.iter().flat_map(|p| [p.0, p.1]).collect();
            assert_bits_eq(&flat_post, &flat_shim, "predictions");
            assert_bits_eq(
                &post.solve_buffer().unwrap(),
                &shim_solves,
                "solve buffer",
            );
            assert_eq!(post.last_cg().unwrap().mvm_rows, shim_cg.mvm_rows);

            // warm variant: an alpha-only guess must agree bit-for-bit too
            let nm = data.n() * data.m();
            let (warm_preds, _, _) = lkgp_fns::predict_final_warm(
                &packed,
                &data,
                &xq,
                &cfg,
                Some(&shim_solves[..nm]),
            )
            .unwrap();
            let mut warm_post =
                Posterior::new(Arc::new(data.clone()), packed.clone(), cfg.clone())
                    .with_guess(Some(shim_solves[..nm].to_vec()));
            let wp = match warm_post
                .answer(&Query::MeanAtFinal { xq: xq.clone() })
                .unwrap()
            {
                Answer::Final(v) => v,
                other => panic!("want Final, got {other:?}"),
            };
            let flat_warm_shim: Vec<f64> =
                warm_preds.iter().flat_map(|p| [p.0, p.1]).collect();
            let flat_warm_post: Vec<f64> = wp.iter().flat_map(|p| [p.0, p.1]).collect();
            assert_bits_eq(&flat_warm_post, &flat_warm_shim, "warm predictions");
        }
    }
}

#[test]
fn predict_mean_shim_is_bit_exact_with_session_steps() {
    let data = adversarial_dataset(4);
    let packed = Theta::default_packed(2);
    let mut rng = Pcg64::new(104);
    let xq = Matrix::from_vec(2, 2, rng.uniform_vec(4, 0.0, 1.0));
    let cfg = SolverCfg::default();
    let (shim_mean, shim_cg) = lkgp_fns::predict_mean(&packed, &data, &xq, &cfg).unwrap();
    let mut post = Posterior::new(Arc::new(data.clone()), packed.clone(), cfg.clone());
    let steps: Vec<usize> = (0..data.m()).collect();
    let mean = match post
        .answer(&Query::MeanAtSteps { xq: xq.clone(), steps })
        .unwrap()
    {
        Answer::Steps(mat) => mat,
        other => panic!("want Steps, got {other:?}"),
    };
    assert_bits_eq(mean.data(), shim_mean.data(), "posterior mean grid");
    assert_eq!(post.last_cg().unwrap().mvm_rows, shim_cg.mvm_rows);
}

#[test]
fn mll_shim_is_bit_exact_with_fit_session() {
    let data = adversarial_dataset(5);
    let mut packed = Theta::default_packed(2);
    packed[0] -= 0.3;
    let nm = data.n() * data.m();
    let cfg = SolverCfg::default();
    let probes = Pcg64::new(9).rademacher_vec(cfg.probes * nm);

    let mut cache = None;
    let (shim_eval, shim_solves) =
        lkgp_fns::mll_value_grad_cached(&packed, &data, &probes, &cfg, None, &mut cache).unwrap();
    let mut session =
        FitSession::with_probes(Arc::new(data.clone()), cfg.clone(), probes.clone()).unwrap();
    let eval = session.eval(&packed).unwrap();
    assert_eq!(eval.value.to_bits(), shim_eval.value.to_bits());
    assert_bits_eq(&eval.grad, &shim_eval.grad, "gradient");
    assert_bits_eq(session.warm_buffer().unwrap(), &shim_solves, "warm buffer");

    // a warm second step must agree too (the shim threads state by hand,
    // the session owns it)
    let mut packed2 = packed.clone();
    packed2[1] += 0.05;
    let (shim_eval2, _) = lkgp_fns::mll_value_grad_cached(
        &packed2,
        &data,
        &probes,
        &cfg,
        Some(&shim_solves),
        &mut cache,
    )
    .unwrap();
    let eval2 = session.eval(&packed2).unwrap();
    assert_eq!(eval2.value.to_bits(), shim_eval2.value.to_bits());
    assert_bits_eq(&eval2.grad, &shim_eval2.grad, "warm gradient");
    assert_eq!(session.evals(), 2);
}

#[test]
fn posterior_samples_shim_is_bit_exact_with_curve_samples_query() {
    let data = adversarial_dataset(6);
    let packed = Theta::default_packed(2);
    let mut rng = Pcg64::new(106);
    let xq = Matrix::from_vec(2, 2, rng.uniform_vec(4, 0.0, 1.0));
    let cfg = SolverCfg::default();
    let seed = 77u64;
    let mut shim_rng = Pcg64::new(seed);
    let shim = lkgp_fns::posterior_samples(&packed, &data, &xq, 3, &cfg, &mut shim_rng).unwrap();
    let mut post = Posterior::new(Arc::new(data.clone()), packed.clone(), cfg.clone());
    let samples = match post
        .answer(&Query::CurveSamples { xq: xq.clone(), n: 3, seed })
        .unwrap()
    {
        Answer::Curves(s) => s,
        other => panic!("want Curves, got {other:?}"),
    };
    assert_eq!(samples.len(), shim.len());
    for (a, b) in samples.iter().zip(&shim) {
        assert_bits_eq(a.data(), b.data(), "sample");
    }
}

/// Dense 6x5 problem, fully observed: session `Variance`/`Quantiles`
/// against the naive dense-Cholesky engine.
#[test]
fn variance_and_quantiles_match_dense_oracle() {
    let (n, m, d) = (6usize, 5usize, 2usize);
    let mut rng = Pcg64::new(31);
    let x = Matrix::from_vec(n, d, rng.uniform_vec(n * d, 0.0, 1.0));
    let t: Vec<f64> = (0..m).map(|i| i as f64 / (m - 1) as f64).collect();
    let mask = Matrix::from_vec(n, m, vec![1.0; n * m]);
    let mut y = Matrix::zeros(n, m);
    for i in 0..n {
        for j in 0..m {
            y[(i, j)] = -0.8 + 0.15 * j as f64 + 0.05 * rng.normal();
        }
    }
    let data = Dataset { x, t, y, mask };
    let packed = Theta::default_packed(d);
    let xq = Matrix::from_vec(3, d, rng.uniform_vec(3 * d, 0.0, 1.0));
    let naive_preds = naive::predict_final_exact(&packed, &data, &xq).unwrap();

    let cfg = SolverCfg { cg_tol: 1e-11, ..Default::default() };
    let mut post = Posterior::new(Arc::new(data), packed, cfg);
    let answers = post
        .answer_batch(&[
            Query::Variance { xq: xq.clone() },
            Query::Quantiles { xq: xq.clone(), ps: vec![0.5, 0.975] },
        ])
        .unwrap();
    assert_eq!(post.solve_calls(), 1, "variance + quantiles share one solve");
    match &answers[0] {
        Answer::Variance(vars) => {
            for (v, want) in vars.iter().zip(&naive_preds) {
                assert!(
                    (v - want.1).abs() < 1e-6,
                    "variance {v} vs dense {}",
                    want.1
                );
            }
        }
        other => panic!("want Variance, got {other:?}"),
    }
    match &answers[1] {
        Answer::Quantiles(q) => {
            for (i, want) in naive_preds.iter().enumerate() {
                // p = 0.5 is exactly the predictive mean
                assert!((q[(i, 0)] - want.0).abs() < 1e-6, "median vs mean");
                // p = 0.975 is mean + 1.959964 sd (known z-value)
                let z = 1.959963985;
                let want_hi = want.0 + z * want.1.sqrt();
                assert!(
                    (q[(i, 1)] - want_hi).abs() < 1e-5,
                    "q97.5 {} vs dense {want_hi}",
                    q[(i, 1)]
                );
            }
        }
        other => panic!("want Quantiles, got {other:?}"),
    }
    let _ = normal_quantile(0.5); // exercised transitively; keep the import honest
}

/// Acceptance: the ServicePool answers >= 3 distinct Query variants
/// through one shard with a single underlying solve per generation,
/// verified via the engine-solve counter, `cg_mvm_rows`, and the keyed
/// warm-cache counters.
#[test]
fn pool_answers_three_variants_with_single_solve_per_generation() {
    fn snapshot() -> Snapshot {
        let mut reg = Registry::new();
        for i in 0..6 {
            let id = reg.add(vec![i as f64 * 0.15, 0.9 - i as f64 * 0.1]);
            for j in 0..3 + i % 3 {
                reg.observe(id, 0.5 + 0.04 * j as f64 + 0.01 * i as f64, 8).unwrap();
            }
        }
        CurveStore::new(8).snapshot(&reg).unwrap()
    }
    let engines: Vec<Box<dyn Engine>> = vec![Box::<RustEngine>::default()];
    let pool = ServicePool::spawn(engines, PoolCfg { workers: 1, ..Default::default() });
    let handle = pool.handle(0);
    let snap = snapshot();
    let theta = Theta::default_packed(2);
    let xq = Matrix::from_vec(2, 2, vec![0.2, 0.6, 0.8, 0.3]);

    let answers = handle
        .query(
            snap.clone(),
            theta.clone(),
            vec![
                Query::MeanAtFinal { xq: xq.clone() },
                Query::Variance { xq: xq.clone() },
                Query::MeanAtSteps { xq: xq.clone(), steps: vec![0, 3, 7] },
            ],
        )
        .unwrap();
    assert_eq!(answers.len(), 3);
    match (&answers[0], &answers[1], &answers[2]) {
        (Answer::Final(f), Answer::Variance(v), Answer::Steps(s)) => {
            assert_eq!(f.len(), 2);
            assert_eq!(v.len(), 2);
            assert_eq!((s.rows(), s.cols()), (2, 3));
            for ((mu, var), vv) in f.iter().zip(v) {
                assert!(mu.is_finite());
                assert!(*var > 0.0);
                assert_eq!(var.to_bits(), vv.to_bits(), "shared solve, same variance");
            }
        }
        other => panic!("unexpected answer shapes: {other:?}"),
    }
    let stats = pool.stats(0);
    assert_eq!(
        stats.engine_solves.load(Ordering::Relaxed),
        1,
        "three variants, one underlying solve"
    );
    let rows_first = stats.cg_mvm_rows.load(Ordering::Relaxed);
    assert!(rows_first > 0, "solve did real MVM work");
    assert_eq!(stats.warm_cache_misses.load(Ordering::Relaxed), 1);

    // same generation again: exact keyed-cache hit, near-free solve
    let again = handle
        .query(snap, theta, vec![Query::MeanAtFinal { xq }])
        .unwrap();
    assert_eq!(again.len(), 1);
    assert!(stats.warm_cache_hits.load(Ordering::Relaxed) >= 1);
    let rows_second = stats.cg_mvm_rows.load(Ordering::Relaxed) - rows_first;
    assert!(
        rows_second * 2 <= rows_first,
        "warm repeat must be far cheaper: {rows_second} vs {rows_first}"
    );
}
