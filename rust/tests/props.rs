//! Property-based integration tests (via testutil::property) over the
//! coordinator and operator invariants — randomized shapes, masks, seeds.

use lkgp::coordinator::{CurveStore, Registry, TrialStatus};
use lkgp::gp::kernels;
use lkgp::gp::operator::MaskedKronOp;
use lkgp::gp::Theta;
use lkgp::linalg::{self, LinOp, Matrix};
use lkgp::testutil::{gen_prefix_mask, gen_usize, property};

#[test]
fn prop_operator_symmetric_psd_any_mask() {
    property(40, |rng| {
        let n = gen_usize(rng, 1, 12);
        let m = gen_usize(rng, 1, 10);
        let d = gen_usize(rng, 1, 4);
        let x = Matrix::from_vec(n, d, rng.uniform_vec(n * d, 0.0, 1.0));
        let ls: Vec<f64> = (0..d).map(|_| rng.uniform_in(0.3, 2.0)).collect();
        let k1 = kernels::rbf(&x, &x, &ls);
        let t: Vec<f64> = (0..m).map(|i| i as f64 / m as f64).collect();
        let k2 = kernels::matern12(&t, &t, rng.uniform_in(0.1, 1.0), rng.uniform_in(0.5, 2.0));
        let mask = Matrix::from_fn(n, m, |_, _| if rng.uniform() < 0.6 { 1.0 } else { 0.0 });
        let s2 = rng.uniform_in(0.01, 0.5);
        let op = MaskedKronOp::new(&k1, &k2, &mask, s2);

        let u = rng.normal_vec(n * m);
        let v = rng.normal_vec(n * m);
        let mut au = vec![0.0; n * m];
        let mut av = vec![0.0; n * m];
        op.apply_batch(&u, &mut au, 1);
        op.apply_batch(&v, &mut av, 1);
        // symmetry
        let uav = linalg::matrix::dot(&u, &av);
        let vau = linalg::matrix::dot(&v, &au);
        assert!((uav - vau).abs() < 1e-8 * (1.0 + uav.abs()));
        // positive definiteness along random directions
        let uau = linalg::matrix::dot(&u, &au);
        assert!(uau > 0.0, "u^T A u = {uau}");
    });
}

#[test]
fn prop_cg_solves_masked_system() {
    property(25, |rng| {
        let n = gen_usize(rng, 2, 8);
        let m = gen_usize(rng, 2, 8);
        let x = Matrix::from_vec(n, 2, rng.uniform_vec(n * 2, 0.0, 1.0));
        let k1 = kernels::rbf(&x, &x, &[1.0, 1.0]);
        let t: Vec<f64> = (0..m).map(|i| i as f64 / m as f64).collect();
        let k2 = kernels::matern12(&t, &t, 0.5, 1.0);
        let mask = gen_prefix_mask(rng, n, m, 1);
        let s2 = rng.uniform_in(0.05, 0.5);
        let op = MaskedKronOp::new(&k1, &k2, &mask, s2);
        let rhs: Vec<f64> = mask.data().iter().map(|&mk| mk * rng.normal()).collect();
        let (sol, stats) = op.solve(&rhs, 1e-9, 3000);
        assert!(stats.converged);
        // verify A x = b on observed entries, x = 0 on missing
        let mut back = vec![0.0; n * m];
        op.apply_batch(&sol, &mut back, 1);
        for i in 0..n * m {
            if mask.data()[i] > 0.0 {
                assert!((back[i] - rhs[i]).abs() < 1e-6);
            } else {
                assert_eq!(sol[i], 0.0);
            }
        }
    });
}

#[test]
fn prop_registry_epoch_accounting() {
    property(30, |rng| {
        let mut reg = Registry::new();
        let k = gen_usize(rng, 1, 10);
        let max_ep = gen_usize(rng, 2, 12);
        let mut expected_total = 0;
        for _ in 0..k {
            let id = reg.add(vec![rng.uniform(), rng.uniform()]);
            let eps = gen_usize(rng, 0, max_ep);
            for e in 0..eps {
                if reg.observe(id, rng.uniform(), max_ep).is_err() {
                    break;
                }
                expected_total += 1;
                let _ = e;
            }
        }
        assert_eq!(reg.total_epochs(), expected_total);
        // completed iff curve length == max_ep
        for t in reg.iter() {
            assert_eq!(
                t.status == TrialStatus::Completed,
                t.epochs_trained() >= max_ep
            );
        }
    });
}

#[test]
fn prop_snapshot_roundtrips_observations() {
    property(20, |rng| {
        let mut reg = Registry::new();
        let k = gen_usize(rng, 1, 8);
        let max_ep = gen_usize(rng, 3, 10);
        let mut curves: Vec<Vec<f64>> = Vec::new();
        for _ in 0..k {
            let id = reg.add(vec![rng.uniform(), rng.uniform(), rng.uniform()]);
            let eps = gen_usize(rng, 1, max_ep - 1);
            let mut c = Vec::new();
            for _ in 0..eps {
                let v = rng.uniform_in(0.2, 0.9);
                reg.observe(id, v, max_ep).unwrap();
                c.push(v);
            }
            curves.push(c);
        }
        let mut store = CurveStore::new(max_ep);
        let snap = store.snapshot(&reg).unwrap();
        // undoing the y-transform must recover raw observations exactly
        for (row, c) in curves.iter().enumerate() {
            for (j, &v) in c.iter().enumerate() {
                assert!(snap.data.mask[(row, j)] > 0.0);
                let back = snap.ytf.undo_mean(snap.data.y[(row, j)]);
                assert!((back - v).abs() < 1e-9, "row={row} j={j}");
            }
            for j in c.len()..max_ep {
                assert_eq!(snap.data.mask[(row, j)], 0.0);
            }
        }
    });
}

#[test]
fn prop_theta_pack_unpack_identity() {
    property(50, |rng| {
        let d = gen_usize(rng, 1, 12);
        let packed: Vec<f64> = (0..d + 3).map(|_| rng.uniform_in(-4.0, 3.0)).collect();
        let theta = Theta::unpack(&packed);
        let back = theta.pack();
        for (a, b) in packed.iter().zip(&back) {
            assert!((a - b).abs() < 1e-12);
        }
        assert!(theta.lengthscales.iter().all(|&l| l > 0.0));
        assert!(theta.sigma2 > 0.0);
    });
}
