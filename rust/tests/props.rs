//! Property-based integration tests (via testutil::property) over the
//! coordinator and operator invariants — randomized shapes, masks, seeds.

use lkgp::coordinator::{CurveStore, Registry, TrialStatus};
use lkgp::gp::kernels;
use lkgp::gp::operator::{dense_masked_kron, MaskedKronOp};
use lkgp::gp::Theta;
use lkgp::linalg::{self, LinOp, Matrix};
use lkgp::rng::Pcg64;
use lkgp::testutil::{gen_prefix_mask, gen_usize, property};

/// Random kernel pair for an (n, m) grid.
fn gen_kernels(rng: &mut Pcg64, n: usize, m: usize, d: usize) -> (Matrix, Matrix) {
    let x = Matrix::from_vec(n, d, rng.uniform_vec(n * d, 0.0, 1.0));
    let ls: Vec<f64> = (0..d).map(|_| rng.uniform_in(0.3, 2.0)).collect();
    let k1 = kernels::rbf(&x, &x, &ls);
    let t: Vec<f64> = (0..m).map(|i| i as f64 / m as f64).collect();
    let k2 = kernels::matern12(&t, &t, rng.uniform_in(0.1, 1.0), rng.uniform_in(0.5, 2.0));
    (k1, k2)
}

/// The four adversarial mask families the operator must survive:
/// all-zero rows, all-zero columns, a single observed entry, full mask.
fn gen_adversarial_mask(rng: &mut Pcg64, n: usize, m: usize, variant: usize) -> Matrix {
    match variant {
        0 => {
            // random mask with several fully-unobserved rows
            let mut mk =
                Matrix::from_fn(n, m, |_, _| if rng.uniform() < 0.6 { 1.0 } else { 0.0 });
            for i in 0..n {
                if rng.uniform() < 0.5 {
                    for j in 0..m {
                        mk[(i, j)] = 0.0;
                    }
                }
            }
            mk
        }
        1 => {
            // fully-unobserved columns (epochs nobody reached)
            let dead: Vec<bool> = (0..m).map(|_| rng.uniform() < 0.5).collect();
            Matrix::from_fn(n, m, |_, j| if dead[j] { 0.0 } else { 1.0 })
        }
        2 => {
            // a single observed entry in the whole grid
            let (ri, cj) = (rng.below(n), rng.below(m));
            Matrix::from_fn(n, m, |i, j| if i == ri && j == cj { 1.0 } else { 0.0 })
        }
        _ => Matrix::from_fn(n, m, |_, _| 1.0),
    }
}

#[test]
fn prop_operator_matches_dense_under_adversarial_masks() {
    property(24, |rng| {
        let n = gen_usize(rng, 2, 9);
        let m = gen_usize(rng, 2, 8);
        let d = gen_usize(rng, 1, 3);
        let (k1, k2) = gen_kernels(rng, n, m, d);
        let s2 = rng.uniform_in(0.05, 0.5);
        for variant in 0..4 {
            let mask = gen_adversarial_mask(rng, n, m, variant);
            let op = MaskedKronOp::new(&k1, &k2, &mask, s2);
            let dense = dense_masked_kron(&k1, &k2, &mask, s2);
            let v = rng.normal_vec(n * m);
            let mut got = vec![0.0; n * m];
            op.apply_batch(&v, &mut got, 1);
            let want = dense.matvec(&v);
            for i in 0..n * m {
                assert!(
                    (got[i] - want[i]).abs() < 1e-9,
                    "variant={variant} i={i}: {} vs {}",
                    got[i],
                    want[i]
                );
            }
            // solves against masked RHS stay supported on the mask
            let rhs: Vec<f64> = mask.data().iter().map(|&mk| mk * rng.normal()).collect();
            let (sol, stats) = op.solve(&rhs, 1e-8, 3000);
            assert!(stats.converged, "variant={variant}");
            for (i, &mk) in mask.data().iter().enumerate() {
                if mk == 0.0 {
                    assert_eq!(sol[i], 0.0, "variant={variant} i={i}");
                }
            }
        }
    });
}

#[test]
fn prop_apply_batch_parallel_bit_identical_to_sequential() {
    // Pin the worker-thread count explicitly so the threaded split is
    // exercised deterministically regardless of the host's core count;
    // also cross-check the default (`apply_batch`) path.
    property(16, |rng| {
        let n = gen_usize(rng, 2, 10);
        let m = gen_usize(rng, 2, 9);
        let (k1, k2) = gen_kernels(rng, n, m, 2);
        let s2 = rng.uniform_in(0.05, 0.5);
        for variant in 0..4 {
            let mask = gen_adversarial_mask(rng, n, m, variant);
            let op = MaskedKronOp::new(&k1, &k2, &mask, s2);
            let batch = gen_usize(rng, 2, 8);
            let nm = n * m;
            let v = rng.normal_vec(batch * nm);
            let mut seq = vec![0.0; batch * nm];
            for b in 0..batch {
                op.apply_batch_with_threads(
                    &v[b * nm..(b + 1) * nm],
                    &mut seq[b * nm..(b + 1) * nm],
                    1,
                    1,
                );
            }
            for threads in [2, 3, 4] {
                let mut got = vec![0.0; batch * nm];
                op.apply_batch_with_threads(&v, &mut got, batch, threads);
                assert_eq!(
                    got, seq,
                    "variant={variant} threads={threads} not bit-identical"
                );
            }
            let mut dflt = vec![0.0; batch * nm];
            op.apply_batch(&v, &mut dflt, batch);
            assert_eq!(dflt, seq, "variant={variant} default path differs");
        }
    });
}

#[test]
fn prop_operator_symmetric_psd_any_mask() {
    property(40, |rng| {
        let n = gen_usize(rng, 1, 12);
        let m = gen_usize(rng, 1, 10);
        let d = gen_usize(rng, 1, 4);
        let x = Matrix::from_vec(n, d, rng.uniform_vec(n * d, 0.0, 1.0));
        let ls: Vec<f64> = (0..d).map(|_| rng.uniform_in(0.3, 2.0)).collect();
        let k1 = kernels::rbf(&x, &x, &ls);
        let t: Vec<f64> = (0..m).map(|i| i as f64 / m as f64).collect();
        let k2 = kernels::matern12(&t, &t, rng.uniform_in(0.1, 1.0), rng.uniform_in(0.5, 2.0));
        let mask = Matrix::from_fn(n, m, |_, _| if rng.uniform() < 0.6 { 1.0 } else { 0.0 });
        let s2 = rng.uniform_in(0.01, 0.5);
        let op = MaskedKronOp::new(&k1, &k2, &mask, s2);

        let u = rng.normal_vec(n * m);
        let v = rng.normal_vec(n * m);
        let mut au = vec![0.0; n * m];
        let mut av = vec![0.0; n * m];
        op.apply_batch(&u, &mut au, 1);
        op.apply_batch(&v, &mut av, 1);
        // symmetry
        let uav = linalg::matrix::dot(&u, &av);
        let vau = linalg::matrix::dot(&v, &au);
        assert!((uav - vau).abs() < 1e-8 * (1.0 + uav.abs()));
        // positive definiteness along random directions
        let uau = linalg::matrix::dot(&u, &au);
        assert!(uau > 0.0, "u^T A u = {uau}");
    });
}

#[test]
fn prop_cg_solves_masked_system() {
    property(25, |rng| {
        let n = gen_usize(rng, 2, 8);
        let m = gen_usize(rng, 2, 8);
        let x = Matrix::from_vec(n, 2, rng.uniform_vec(n * 2, 0.0, 1.0));
        let k1 = kernels::rbf(&x, &x, &[1.0, 1.0]);
        let t: Vec<f64> = (0..m).map(|i| i as f64 / m as f64).collect();
        let k2 = kernels::matern12(&t, &t, 0.5, 1.0);
        let mask = gen_prefix_mask(rng, n, m, 1);
        let s2 = rng.uniform_in(0.05, 0.5);
        let op = MaskedKronOp::new(&k1, &k2, &mask, s2);
        let rhs: Vec<f64> = mask.data().iter().map(|&mk| mk * rng.normal()).collect();
        let (sol, stats) = op.solve(&rhs, 1e-9, 3000);
        assert!(stats.converged);
        // verify A x = b on observed entries, x = 0 on missing
        let mut back = vec![0.0; n * m];
        op.apply_batch(&sol, &mut back, 1);
        for i in 0..n * m {
            if mask.data()[i] > 0.0 {
                assert!((back[i] - rhs[i]).abs() < 1e-6);
            } else {
                assert_eq!(sol[i], 0.0);
            }
        }
    });
}

#[test]
fn prop_registry_epoch_accounting() {
    property(30, |rng| {
        let mut reg = Registry::new();
        let k = gen_usize(rng, 1, 10);
        let max_ep = gen_usize(rng, 2, 12);
        let mut expected_total = 0;
        for _ in 0..k {
            let id = reg.add(vec![rng.uniform(), rng.uniform()]);
            let eps = gen_usize(rng, 0, max_ep);
            for e in 0..eps {
                if reg.observe(id, rng.uniform(), max_ep).is_err() {
                    break;
                }
                expected_total += 1;
                let _ = e;
            }
        }
        assert_eq!(reg.total_epochs(), expected_total);
        // completed iff curve length == max_ep
        for t in reg.iter() {
            assert_eq!(
                t.status == TrialStatus::Completed,
                t.epochs_trained() >= max_ep
            );
        }
    });
}

#[test]
fn prop_snapshot_roundtrips_observations() {
    property(20, |rng| {
        let mut reg = Registry::new();
        let k = gen_usize(rng, 1, 8);
        let max_ep = gen_usize(rng, 3, 10);
        let mut curves: Vec<Vec<f64>> = Vec::new();
        for _ in 0..k {
            let id = reg.add(vec![rng.uniform(), rng.uniform(), rng.uniform()]);
            let eps = gen_usize(rng, 1, max_ep - 1);
            let mut c = Vec::new();
            for _ in 0..eps {
                let v = rng.uniform_in(0.2, 0.9);
                reg.observe(id, v, max_ep).unwrap();
                c.push(v);
            }
            curves.push(c);
        }
        let mut store = CurveStore::new(max_ep);
        let snap = store.snapshot(&reg).unwrap();
        // undoing the y-transform must recover raw observations exactly
        for (row, c) in curves.iter().enumerate() {
            for (j, &v) in c.iter().enumerate() {
                assert!(snap.data.mask[(row, j)] > 0.0);
                let back = snap.ytf.undo_mean(snap.data.y[(row, j)]);
                assert!((back - v).abs() < 1e-9, "row={row} j={j}");
            }
            for j in c.len()..max_ep {
                assert_eq!(snap.data.mask[(row, j)], 0.0);
            }
        }
    });
}

#[test]
fn prop_theta_pack_unpack_identity() {
    property(50, |rng| {
        let d = gen_usize(rng, 1, 12);
        let packed: Vec<f64> = (0..d + 3).map(|_| rng.uniform_in(-4.0, 3.0)).collect();
        let theta = Theta::unpack(&packed);
        let back = theta.pack();
        for (a, b) in packed.iter().zip(&back) {
            assert!((a - b).abs() < 1e-12);
        }
        assert!(theta.lengthscales.iter().all(|&l| l > 0.0));
        assert!(theta.sigma2 > 0.0);
    });
}
