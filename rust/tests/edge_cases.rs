//! Edge-case and failure-injection integration tests.

#![allow(deprecated)] // exercises the deprecated free-function shims by design

use lkgp::gp::lkgp::{Dataset, SolverCfg};
use lkgp::gp::transforms::{XTransform, YTransform};
use lkgp::gp::Theta;
use lkgp::linalg::Matrix;
use lkgp::rng::Pcg64;
use lkgp::runtime::{Engine, RustEngine};

/// A single training curve with a single observation — the smallest
/// problem the coordinator can hand the engine on round one.
#[test]
fn single_curve_single_observation() {
    let data = Dataset {
        x: Matrix::from_vec(1, 3, vec![0.5, 0.5, 0.5]),
        t: (0..8).map(|i| i as f64 / 7.0).collect(),
        y: Matrix::from_vec(1, 8, vec![-1.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0]),
        mask: Matrix::from_vec(1, 8, vec![1.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0]),
    };
    let mut eng = RustEngine::default();
    let theta0 = Theta::default_packed(3);
    let theta = eng.fit(&theta0, &data, 1).unwrap();
    let xq = Matrix::from_vec(1, 3, vec![0.4, 0.6, 0.5]);
    let preds = eng.predict_final(&theta, &data, &xq).unwrap();
    assert!(preds[0].0.is_finite());
    assert!(preds[0].1 > 0.0);
    // with one observation at t=0 the final-epoch prediction must carry
    // substantial uncertainty
    assert!(preds[0].1.sqrt() > 0.05);
}

/// Fully observed data: the masked operator degenerates to the plain
/// Kronecker case and everything still works.
#[test]
fn fully_observed_curves() {
    let mut data = lkgp::lcbench::toy_dataset(6, 10, 2, 3);
    for v in data.mask.data_mut().iter_mut() {
        *v = 1.0;
    }
    let packed = Theta::default_packed(2);
    let mut rng = Pcg64::new(4);
    let probes = rng.rademacher_vec(16 * 60);
    let cfg = SolverCfg { probes: 16, ..Default::default() };
    let eval = lkgp::gp::lkgp::mll_value_grad(&packed, &data, &probes, &cfg).unwrap();
    assert!(eval.value.is_finite());
    assert!(eval.cg.converged);
}

/// Extremely short prefixes everywhere (1 epoch observed per curve).
#[test]
fn one_epoch_prefixes() {
    let n = 8;
    let m = 12;
    let mut rng = Pcg64::new(5);
    let data = Dataset {
        x: Matrix::from_vec(n, 2, rng.uniform_vec(n * 2, 0.0, 1.0)),
        t: (0..m).map(|i| i as f64 / (m - 1) as f64).collect(),
        y: {
            let mut y = Matrix::zeros(n, m);
            for i in 0..n {
                y[(i, 0)] = rng.normal() * 0.1 - 1.0;
            }
            y
        },
        mask: {
            let mut mk = Matrix::zeros(n, m);
            for i in 0..n {
                mk[(i, 0)] = 1.0;
            }
            mk
        },
    };
    let mut eng = RustEngine::default();
    let theta = eng.fit(&Theta::default_packed(2), &data, 6).unwrap();
    let samples = eng
        .sample_curves(&theta, &data, &Matrix::from_vec(1, 2, vec![0.5, 0.5]), 8, 7)
        .unwrap();
    for s in &samples {
        for v in s.data() {
            assert!(v.is_finite());
        }
    }
}

/// Query configs far outside the training hypercube (transform clamps).
#[test]
fn out_of_range_queries_are_clamped() {
    let x = Matrix::from_vec(3, 2, vec![1.0, 10.0, 2.0, 20.0, 3.0, 30.0]);
    let tf = XTransform::fit(&x);
    let wild = Matrix::from_vec(2, 2, vec![-100.0, 5.0, 100.0, 500.0]);
    let z = tf.apply(&wild);
    for v in z.data() {
        assert!((-1.0..=2.0).contains(v), "{v}");
    }
}

/// Constant observed outputs: YTransform must not divide by ~0.
#[test]
fn constant_outputs_standardize_safely() {
    let y = Matrix::from_vec(2, 3, vec![0.7; 6]);
    let mask = Matrix::from_vec(2, 3, vec![1.0; 6]);
    let tf = YTransform::fit(&y, &mask);
    let z = tf.apply(&y, &mask);
    for v in z.data() {
        assert!(v.is_finite());
    }
    assert!((tf.undo_mean(z[(0, 0)]) - 0.7).abs() < 1e-9);
}

/// Matheron sampling is deterministic given the seed.
#[test]
fn sampling_deterministic_given_seed() {
    let data = lkgp::lcbench::toy_dataset(6, 8, 2, 8);
    let theta = Theta::default_packed(2);
    let xq = Matrix::from_vec(1, 2, vec![0.3, 0.7]);
    let mut eng = RustEngine::default();
    let a = eng.sample_curves(&theta, &data, &xq, 4, 99).unwrap();
    let b = eng.sample_curves(&theta, &data, &xq, 4, 99).unwrap();
    for (sa, sb) in a.iter().zip(&b) {
        assert_eq!(sa.data(), sb.data());
    }
    let c = eng.sample_curves(&theta, &data, &xq, 4, 100).unwrap();
    assert_ne!(a[0].data(), c[0].data());
}

/// Mismatched dataset shapes are rejected, not UB.
#[test]
fn shape_errors_are_reported() {
    let bad = Dataset {
        x: Matrix::zeros(4, 2),
        t: vec![0.0, 0.5, 1.0],
        y: Matrix::zeros(4, 5), // wrong m
        mask: Matrix::zeros(4, 3),
    };
    assert!(bad.check().is_err());
    let mut rng = Pcg64::new(1);
    let probes = rng.rademacher_vec(8 * 12);
    let cfg = SolverCfg::default();
    assert!(lkgp::gp::lkgp::mll_value_grad(&Theta::default_packed(2), &bad, &probes, &cfg).is_err());
}

/// Extreme hyper-parameters keep the solver finite (trainer line-search
/// probes walk into these regions).
#[test]
fn extreme_theta_stays_finite() {
    let data = lkgp::lcbench::toy_dataset(6, 8, 2, 9);
    let mut rng = Pcg64::new(10);
    let probes = rng.rademacher_vec(8 * 48);
    let cfg = SolverCfg { cg_max_iters: 500, ..Default::default() };
    for packed in [
        vec![-6.0, -6.0, -6.0, 4.0, -9.0],  // tiny lengthscales, tiny noise
        vec![6.0, 6.0, 6.0, -6.0, 2.0],     // huge lengthscales, huge noise
    ] {
        let eval = lkgp::gp::lkgp::mll_value_grad(&packed, &data, &probes, &cfg).unwrap();
        assert!(eval.value.is_finite(), "{packed:?}");
        for g in &eval.grad {
            assert!(g.is_finite());
        }
    }
}

/// mll_exact and the naive engine agree on a non-prefix (scattered) mask.
#[test]
fn scattered_masks_supported() {
    let mut rng = Pcg64::new(11);
    let (n, m) = (7, 6);
    let data = Dataset {
        x: Matrix::from_vec(n, 2, rng.uniform_vec(n * 2, 0.0, 1.0)),
        t: (0..m).map(|i| i as f64 / (m - 1) as f64).collect(),
        y: Matrix::from_vec(n, m, rng.normal_vec(n * m)),
        mask: Matrix::from_fn(n, m, |_, _| if rng.uniform() < 0.5 { 1.0 } else { 0.0 }),
    };
    // zero out unobserved y like the transforms do
    let mut data = data;
    let mask = data.mask.clone();
    for (yv, mv) in data.y.data_mut().iter_mut().zip(mask.data()) {
        *yv *= mv;
    }
    let packed = Theta::default_packed(2);
    let a = lkgp::gp::naive::mll_value_grad_exact(&packed, &data).unwrap().0;
    let b = lkgp::gp::lkgp::mll_exact(&packed, &data).unwrap();
    assert!((a - b).abs() < 1e-9);
}

/// CG handles a zero right-hand side without dividing by zero.
#[test]
fn cg_zero_rhs() {
    let data = lkgp::lcbench::toy_dataset(5, 6, 2, 12);
    let theta = Theta::unpack(&Theta::default_packed(2));
    let k1 = lkgp::gp::kernels::rbf(&data.x, &data.x, &theta.lengthscales);
    let k2 = lkgp::gp::kernels::matern12(&data.t, &data.t, theta.t_lengthscale, theta.outputscale);
    let op = lkgp::gp::operator::MaskedKronOp::new(&k1, &k2, &data.mask, theta.sigma2);
    let rhs = vec![0.0; 30];
    let (x, stats) = op.solve(&rhs, 1e-8, 100);
    assert_eq!(stats.iters, 0);
    assert!(x.iter().all(|&v| v == 0.0));
}
