//! Record/replay e2e: live scheduler traffic recorded through
//! `RecordingHandle` must replay — sequentially and concurrently — with
//! zero errors and intact invariants, and the checked-in v1 smoke must
//! pass the concurrent storm + parity pass.

use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use lkgp::coordinator::trace::run_replay;
use lkgp::coordinator::{
    CorpusRunner, CurveStore, EngineFactory, PoolCfg, PredictClient, RecordingHandle, Registry,
    Scheduler, SchedulerCfg, ServicePool, TraceRecorder, TrialId,
};
use lkgp::lcbench::corpus::{Corpus, SimCorpus};
use lkgp::linalg::Matrix;
use lkgp::runtime::{Engine, RustEngine};

fn repo_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("rust/ lives under the repo root")
        .to_path_buf()
}

fn scratch_file(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!(
        "lkgp_trace_{tag}_{}_{}.jsonl",
        std::process::id(),
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos()
    ))
}

/// Run two small freeze-thaw schedulers over a sim corpus with recording
/// on, returning the recorded trace path.
fn record_run(path: &PathBuf) {
    let corpus = SimCorpus::new(2, 8, 23);
    let factory: EngineFactory = Box::new(|_| Box::<RustEngine>::default() as Box<dyn Engine>);
    let pool = ServicePool::from_corpus(
        &corpus,
        factory,
        PoolCfg { workers: 2, ..Default::default() },
    );
    let recorder = Arc::new(Mutex::new(
        TraceRecorder::new(&corpus, path.to_str().unwrap()).unwrap(),
    ));
    std::thread::scope(|scope| {
        let mut joins = Vec::new();
        for t in 0..corpus.len() {
            let task = corpus.task(t).unwrap();
            let handle = pool.handle(t);
            let rec = recorder.clone();
            joins.push(scope.spawn(move || {
                let cfg = SchedulerCfg {
                    max_concurrent: 3,
                    refit_every: 3,
                    epoch_budget: 24,
                    seed: 23 + t as u64,
                    ..Default::default()
                };
                let mut sched = Scheduler::new(task.m(), cfg);
                let configs: Vec<Vec<f64>> =
                    (0..task.n()).map(|i| task.configs.row(i).to_vec()).collect();
                sched.add_candidates(&configs);
                let client = RecordingHandle::new(handle, t, rec);
                let mut runner = CorpusRunner { task };
                sched.run(&mut runner, &client).unwrap();
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
    });
    recorder.lock().unwrap().finish(&pool).unwrap();
}

#[test]
fn recorded_trace_replays_sequentially_and_concurrently() {
    let path = scratch_file("roundtrip");
    record_run(&path);
    let text = std::fs::read_to_string(&path).unwrap();
    assert!(text.contains("\"version\":2"));
    assert!(text.contains("\"lengths\""), "gen lines must be recorded");
    assert!(text.contains("\"refit\""), "refit lines must be recorded");
    assert!(text.contains("\"queries\""), "query lines must be recorded");
    assert!(text.contains("\"fingerprint\":\"sim-t2-c8-s23\""));

    // sequential: zero errors, relaxed v2 equalities hold
    let seq = run_replay(path.to_str().unwrap(), false, None).unwrap();
    assert!(seq.requests > 0, "trace must carry query requests");
    assert!(seq.refits > 0, "trace must carry refit (write) requests");
    assert_eq!(seq.errors, 0);
    assert!(seq.violations.is_empty(), "{:?}", seq.violations);

    // concurrent: the storm + parity pass
    let con = run_replay(path.to_str().unwrap(), true, None).unwrap();
    assert_eq!(con.errors, 0);
    assert!(con.violations.is_empty(), "{:?}", con.violations);
    assert!(con.parity_checks > 0, "parity pass must run");
    std::fs::remove_file(&path).ok();
}

/// Seeded `CurveSamples` requests are trace-representable: the recorder
/// writes them as `curve_samples` lines, the replay re-submits them, and
/// the concurrent parity pass asserts the draws come back bit for bit —
/// the sampling determinism contract of docs/sampling.md, end to end.
#[test]
fn recorded_curve_samples_replay_with_bitwise_parity() {
    let path = scratch_file("samples");
    let corpus = SimCorpus::new(1, 6, 31);
    let factory: EngineFactory = Box::new(|_| Box::<RustEngine>::default() as Box<dyn Engine>);
    let pool = ServicePool::from_corpus(
        &corpus,
        factory,
        PoolCfg { workers: 1, ..Default::default() },
    );
    let recorder = Arc::new(Mutex::new(
        TraceRecorder::new(&corpus, path.to_str().unwrap()).unwrap(),
    ));
    let task = corpus.task(0).unwrap();
    let mut reg = Registry::new();
    for i in 0..task.n() {
        reg.add(task.configs.row(i).to_vec());
    }
    for i in 0..task.n() {
        reg.observe(TrialId(i), task.curves[(i, 0)], task.m()).unwrap();
    }
    let mut store = CurveStore::new(task.m());
    let snap = store.snapshot(&reg).unwrap();
    let client = RecordingHandle::new(pool.handle(0), 0, recorder.clone());
    let theta = client.refit(snap.clone(), vec![], 5).unwrap();

    // two registered configs as the query block (rows resolve bitwise)
    let d = snap.all_x.cols();
    let mut xq = Matrix::zeros(2, d);
    for r in 0..2 {
        xq.row_mut(r).copy_from_slice(snap.all_x.row(r));
    }
    let a = client
        .sample_curves(snap.clone(), theta.clone(), xq.clone(), 3, 77)
        .unwrap();
    let b = client.sample_curves(snap, theta, xq, 3, 77).unwrap();
    assert_eq!(a.len(), 3);
    for (x, y) in a.iter().zip(&b) {
        assert!(
            x.data().iter().zip(y.data()).all(|(p, q)| p.to_bits() == q.to_bits()),
            "same seed through the same lineage must draw bitwise-identical curves"
        );
    }
    recorder.lock().unwrap().finish(&pool).unwrap();

    let text = std::fs::read_to_string(&path).unwrap();
    assert!(
        text.contains("\"kind\":\"curve_samples\""),
        "seeded sampling must be recorded, not skipped: {text}"
    );
    assert!(text.contains("\"seed\":77"));

    let seq = run_replay(path.to_str().unwrap(), false, None).unwrap();
    assert_eq!(seq.errors, 0);
    assert!(seq.violations.is_empty(), "{:?}", seq.violations);
    assert_eq!(seq.requests, 2, "both sampling requests replay");

    // the parity pass replays each distinct seeded request twice and
    // requires Answer::Curves to agree bit for bit
    let con = run_replay(path.to_str().unwrap(), true, None).unwrap();
    assert_eq!(con.errors, 0);
    assert!(con.violations.is_empty(), "{:?}", con.violations);
    assert!(con.parity_checks >= 1);
    std::fs::remove_file(&path).ok();
}

#[test]
fn tampered_fingerprint_refuses_to_replay() {
    let path = scratch_file("tamper");
    record_run(&path);
    let text = std::fs::read_to_string(&path).unwrap();
    let tampered = text.replace("sim-t2-c8-s23", "sim-t2-c8-s99");
    std::fs::write(&path, tampered).unwrap();
    let err = run_replay(path.to_str().unwrap(), false, None);
    assert!(err.is_err(), "fingerprint drift must refuse to replay");
    std::fs::remove_file(&path).ok();
}

#[test]
fn v1_smoke_replays_concurrently_with_parity() {
    let smoke = repo_root().join("traces/smoke.jsonl");
    let summary = run_replay(smoke.to_str().unwrap(), true, None).unwrap();
    assert_eq!(summary.errors, 0);
    assert!(summary.violations.is_empty(), "{:?}", summary.violations);
    assert_eq!(summary.requests, 18, "smoke carries 18 requests");
    assert!(summary.parity_checks >= 18);
}
