//! Warm-started CG correctness: a warm start must never change *what* the
//! solver converges to, only how fast it gets there.

use lkgp::gp::kernels;
use lkgp::gp::operator::MaskedKronOp;
use lkgp::gp::Theta;
use lkgp::lcbench::toy_dataset;
use lkgp::linalg::cg::DenseOp;
use lkgp::linalg::{cg_batch, cg_batch_warm};
use lkgp::rng::Pcg64;
use lkgp::testutil::gen_spd;

#[test]
fn random_guess_converges_to_the_cold_solution() {
    let mut rng = Pcg64::new(1);
    let n = 48;
    let a = gen_spd(&mut rng, n, 0.5);
    let b = rng.normal_vec(n);
    let guess = rng.normal_vec(n);
    let (cold, cs) = cg_batch(&DenseOp(&a), &b, 1e-10, 1000);
    let (warm, ws) = cg_batch_warm(&DenseOp(&a), &b, Some(&guess), 1e-10, 1000);
    assert!(cs.converged && ws.converged);
    for i in 0..n {
        assert!((cold[i] - warm[i]).abs() < 1e-6, "i={i}");
    }
}

#[test]
fn exact_solution_guess_converges_almost_instantly() {
    let mut rng = Pcg64::new(2);
    let n = 40;
    let a = gen_spd(&mut rng, n, 0.5);
    let b = rng.normal_vec(n);
    let (x, _) = cg_batch(&DenseOp(&a), &b, 1e-12, 2000);
    let (x2, stats) = cg_batch_warm(&DenseOp(&a), &b, Some(&x), 1e-8, 2000);
    assert!(stats.iters <= 2, "iters={}", stats.iters);
    assert!(stats.converged);
    for i in 0..n {
        assert!((x[i] - x2[i]).abs() < 1e-6);
    }
}

#[test]
fn incremental_mask_refit_needs_fewer_iterations_warm() {
    // The scheduler workload: generation g+1 differs from g by one more
    // observed epoch per curve. Warm-starting from generation g's solves
    // must converge to the same quality in fewer iterations.
    let (n, m) = (24usize, 16usize);
    let gen1 = toy_dataset(n, m, 3, 5);
    let mut gen2 = gen1.clone();
    for i in 0..n {
        let len = (0..m).take_while(|&j| gen1.mask[(i, j)] > 0.0).count();
        if len < m {
            let prev = gen2.y[(i, len - 1)];
            gen2.mask[(i, len)] = 1.0;
            gen2.y[(i, len)] = prev;
        }
    }
    let theta = Theta::unpack(&Theta::default_packed(3));
    let k1 = kernels::rbf(&gen1.x, &gen1.x, &theta.lengthscales);
    let k2 = kernels::matern12(&gen1.t, &gen1.t, theta.t_lengthscale, theta.outputscale);
    let op1 = MaskedKronOp::new(&k1, &k2, &gen1.mask, theta.sigma2);
    let op2 = MaskedKronOp::new(&k1, &k2, &gen2.mask, theta.sigma2);

    let (alpha1, _) = op1.solve(gen1.y.data(), 1e-6, 5000);
    let (_, cold) = op2.solve(gen2.y.data(), 1e-6, 5000);
    let (warm_sol, warm) = op2.solve_warm(gen2.y.data(), Some(&alpha1), 1e-6, 5000);
    assert!(cold.converged && warm.converged);
    assert!(
        warm.iters < cold.iters,
        "warm {} vs cold {}",
        warm.iters,
        cold.iters
    );
    // same converged system: residual quality matches the cold solve
    let mut back = vec![0.0; n * m];
    use lkgp::linalg::LinOp;
    op2.apply_batch(&warm_sol, &mut back, 1);
    for (i, (&bi, &yi)) in back.iter().zip(gen2.y.data()).enumerate() {
        if gen2.mask.data()[i] > 0.0 {
            assert!((bi - yi).abs() < 1e-4, "i={i}");
        }
    }
}

#[test]
fn warm_fit_reaches_the_same_quality_as_cold_objective() {
    // RustEngine::fit threads warm solves across optimizer steps; the
    // fitted hyper-parameters must still improve the exact MAP objective.
    use lkgp::runtime::{Engine, RustEngine};
    let data = toy_dataset(10, 12, 3, 7);
    let theta0 = Theta::default_packed(3);
    let before = lkgp::gp::lkgp::mll_exact(&theta0, &data).unwrap();
    let mut eng = RustEngine::default();
    let theta = eng.fit(&theta0, &data, 3).unwrap();
    let after = lkgp::gp::lkgp::mll_exact(&theta, &data).unwrap();
    assert!(after > before, "{before} -> {after}");
}
