//! Tier-1 lint gate: the in-tree invariant analyzer (docs/static_analysis.md)
//! must pass on the crate's own sources under plain `cargo test`, and each
//! rule family must fire on its fixture in `tests/lint_fixtures/` (plain
//! text, never compiled).
//!
//! `shipped_tree_is_clean` is the gate itself: any unjustified finding —
//! a lock-order cycle, a poison-policy mismatch, an undocumented
//! `unsafe`, a naked hot-path panic, a float `==`, dead telemetry, an
//! ungated bench artifact, or a malformed pragma — fails `cargo test`
//! before ci.sh even reaches the dedicated `lint` gate.

use lkgp::analysis::{
    analyze, analyze_source, AnalysisConfig, AnalysisInput, LockPolicy, Rule,
};
use std::path::Path;

fn fixture(name: &str) -> String {
    let path = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/lint_fixtures")
        .join(name);
    std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("read fixture {}: {e}", path.display()))
}

fn cfg(policies: &[(&str, LockPolicy)], hot_paths: &[&str], stats_struct: &str) -> AnalysisConfig {
    AnalysisConfig {
        lock_policies: policies
            .iter()
            .map(|(n, p)| (n.to_string(), *p))
            .collect(),
        hot_paths: hot_paths.iter().map(|s| s.to_string()).collect(),
        float_exempt: Vec::new(),
        stats_struct: stats_struct.into(),
    }
}

/// (line, justified) pairs of the findings for one rule, sorted.
fn hits(a: &lkgp::analysis::Analysis, rule: Rule) -> Vec<(u32, bool)> {
    let mut v: Vec<(u32, bool)> = a
        .findings
        .iter()
        .filter(|f| f.rule == rule)
        .map(|f| (f.line, f.justified.is_some()))
        .collect();
    v.sort_unstable();
    v
}

// ---------------------------------------------------------------------------
// the gate: the shipped tree itself
// ---------------------------------------------------------------------------

#[test]
fn shipped_tree_is_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let input = AnalysisInput::load(root).expect("load crate sources");
    let report = analyze(&input, &AnalysisConfig::crate_default());
    let bad: Vec<String> = report
        .unjustified()
        .iter()
        .map(|f| format!("{}:{} [{}] {}", f.file, f.line, f.rule.name(), f.message))
        .collect();
    assert!(
        bad.is_empty(),
        "unjustified lint findings in the shipped tree:\n{}",
        bad.join("\n")
    );
    // Sanity: the analyzer actually saw the crate, not an empty walk.
    assert!(report.files_scanned >= 20, "only {} files scanned", report.files_scanned);
    assert!(!report.lock_sites.is_empty(), "no lock sites found");
    assert!(!report.unsafe_sites.is_empty(), "no unsafe sites found");
}

#[test]
fn shipped_unsafe_inventory_is_fully_documented() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let input = AnalysisInput::load(root).expect("load crate sources");
    let report = analyze(&input, &AnalysisConfig::crate_default());
    let undocumented: Vec<String> = report
        .unsafe_sites
        .iter()
        .filter(|s| s.safety.is_none())
        .map(|s| format!("{}:{} ({})", s.file, s.line, s.kind))
        .collect();
    assert!(
        undocumented.is_empty(),
        "unsafe sites without a SAFETY comment:\n{}",
        undocumented.join("\n")
    );
}

#[test]
fn analysis_json_round_trips() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let input = AnalysisInput::load(root).expect("load crate sources");
    let report = analyze(&input, &AnalysisConfig::crate_default());
    let text = report.to_json().pretty();
    let parsed = lkgp::json::Json::parse(&text).expect("ANALYSIS.json parses back");
    // Schema spot checks (docs/static_analysis.md).
    let n = parsed.get("files_scanned").and_then(|j| j.as_usize());
    assert_eq!(n, Some(report.files_scanned));
    let sites = parsed.get("unsafe_sites").and_then(|j| j.as_arr());
    assert_eq!(sites.map(|s| s.len()), Some(report.unsafe_sites.len()));
    let edges = parsed.get("lock_edges").and_then(|j| j.as_arr());
    assert_eq!(edges.map(|e| e.len()), Some(report.lock_edges.len()));
}

// ---------------------------------------------------------------------------
// fixtures: each rule family fires exactly where it should
// ---------------------------------------------------------------------------

#[test]
fn fixture_lock_cycle_is_rejected() {
    use LockPolicy::FailLoud;
    let c = cfg(&[("alpha", FailLoud), ("beta", FailLoud)], &[], "NoStats");
    let a = analyze_source("lock_cycle.rs", &fixture("lock_cycle.rs"), &c);
    let order: Vec<_> = a.findings.iter().filter(|f| f.rule == Rule::LockOrder).collect();
    assert_eq!(order.len(), 1, "{:?}", a.findings);
    assert!(order[0].message.contains("alpha") && order[0].message.contains("beta"));
    // The witness is the call-graph edge: beta held at line 20 across `tail`.
    assert_eq!(order[0].line, 20);
    assert!(order[0].message.contains("tail"), "{}", order[0].message);
    // Both edges made it into the inventory, with the call edge attributed.
    assert!(a.lock_edges.iter().any(|e| e.from == "alpha" && e.to == "beta" && e.via == "direct"));
    assert!(a.lock_edges.iter().any(|e| e.from == "beta" && e.to == "alpha" && e.via == "tail"));
    // No other rule fires on this fixture.
    assert_eq!(a.findings.len(), 1, "{:?}", a.findings);
}

#[test]
fn fixture_consistent_order_passes() {
    use LockPolicy::FailLoud;
    // Same fixture minus the inverted function: alpha -> beta only.
    let text = fixture("lock_cycle.rs");
    let consistent: String = text
        .lines()
        .take_while(|l| !l.starts_with("pub fn backward"))
        .map(|l| format!("{l}\n"))
        .collect();
    let c = cfg(&[("alpha", FailLoud), ("beta", FailLoud)], &[], "NoStats");
    let a = analyze_source("consistent.rs", &consistent, &c);
    assert!(a.findings.is_empty(), "{:?}", a.findings);
    assert!(a.lock_edges.iter().all(|e| e.from == "alpha" && e.to == "beta"));
}

#[test]
fn fixture_missing_safety_is_flagged() {
    let a = analyze_source(
        "missing_safety.rs",
        &fixture("missing_safety.rs"),
        &AnalysisConfig::crate_default(),
    );
    assert_eq!(hits(&a, Rule::UnsafeSafety), vec![(5, false)], "{:?}", a.findings);
    // Inventory carries both sites; only the documented one has text.
    assert_eq!(a.unsafe_sites.len(), 2);
    let documented = a.unsafe_sites.iter().find(|s| s.line == 11).unwrap();
    assert!(documented.safety.as_deref().unwrap_or("").starts_with("fixture contract"));
    assert!(a.unsafe_sites.iter().find(|s| s.line == 5).unwrap().safety.is_none());
}

#[test]
fn fixture_naked_unwrap_is_flagged_with_pragma_honored() {
    let c = cfg(&[], &["naked_unwrap.rs"], "NoStats");
    let a = analyze_source("naked_unwrap.rs", &fixture("naked_unwrap.rs"), &c);
    // unwrap(7) + expect(8) + unreachable!(10) unjustified; the pragma'd
    // unwrap(14) is reported but justified; the poison-protocol
    // `.wait(..).unwrap()` at 12 is exempt.
    assert_eq!(
        hits(&a, Rule::Panic),
        vec![(7, false), (8, false), (10, false), (14, true)],
        "{:?}",
        a.findings
    );
    assert_eq!(a.unjustified().len(), 3);
}

#[test]
fn fixture_hot_path_scoping_applies() {
    // The same panic-laden file outside the hot-path set is not a finding.
    let c = cfg(&[], &["some/other/module.rs"], "NoStats");
    let a = analyze_source("naked_unwrap.rs", &fixture("naked_unwrap.rs"), &c);
    assert!(hits(&a, Rule::Panic).is_empty(), "{:?}", a.findings);
}

#[test]
fn fixture_float_discipline_is_flagged() {
    let a = analyze_source(
        "float_eq.rs",
        &fixture("float_eq.rs"),
        &AnalysisConfig::crate_default(),
    );
    assert_eq!(hits(&a, Rule::FloatEq), vec![(6, false)], "{:?}", a.findings);
    assert_eq!(hits(&a, Rule::FloatCmp), vec![(10, false)], "{:?}", a.findings);
    // to_bits identity and tolerance compares stay clean.
    assert_eq!(a.findings.len(), 2, "{:?}", a.findings);
}

#[test]
fn fixture_float_exempt_module_passes() {
    let mut c = AnalysisConfig::crate_default();
    c.float_exempt.push("parity/".into());
    let a = analyze_source("parity/float_eq.rs", &fixture("float_eq.rs"), &c);
    assert!(a.findings.is_empty(), "{:?}", a.findings);
}

#[test]
fn fixture_dead_counter_is_flagged() {
    let c = cfg(&[], &[], "FixtureStats");
    let a = analyze_source("dead_counter.rs", &fixture("dead_counter.rs"), &c);
    let drift = hits(&a, Rule::StatsDrift);
    assert_eq!(drift, vec![(8, false)], "{:?}", a.findings);
    let f = a.findings.iter().find(|f| f.rule == Rule::StatsDrift).unwrap();
    assert!(f.message.contains("misses"), "{}", f.message);
    assert_eq!(a.findings.len(), 1, "{:?}", a.findings);
}

#[test]
fn fixture_poison_policy_mismatches_both_ways() {
    use LockPolicy::{FailLoud, Recover};
    let c = cfg(&[("work", FailLoud), ("memo", Recover)], &[], "NoStats");
    let a = analyze_source("poison_policy.rs", &fixture("poison_policy.rs"), &c);
    assert_eq!(
        hits(&a, Rule::PoisonPolicy),
        vec![(12, false), (17, false)],
        "{:?}",
        a.findings
    );
    assert_eq!(a.findings.len(), 2, "{:?}", a.findings);
    // Swapping the registrations to match the shapes clears both.
    let c = cfg(&[("work", Recover), ("memo", FailLoud)], &[], "NoStats");
    let a = analyze_source("poison_policy.rs", &fixture("poison_policy.rs"), &c);
    assert!(a.findings.is_empty(), "{:?}", a.findings);
}

#[test]
fn fixture_unregistered_lock_class_is_flagged() {
    // Same fixture, but `memo` missing from the policy table: new locks
    // cannot land unclassified.
    let c = cfg(&[("work", LockPolicy::Recover)], &[], "NoStats");
    let a = analyze_source("poison_policy.rs", &fixture("poison_policy.rs"), &c);
    let classes = hits(&a, Rule::LockClass);
    assert_eq!(classes, vec![(8, false)], "{:?}", a.findings);
}

#[test]
fn fixture_clean_file_passes_everything() {
    use LockPolicy::FailLoud;
    let c = cfg(
        &[("first", FailLoud), ("second", FailLoud)],
        &["clean.rs"],
        "CleanStats",
    );
    let a = analyze_source("clean.rs", &fixture("clean.rs"), &c);
    assert!(a.findings.is_empty(), "{:?}", a.findings);
    // The compliant file still populates the inventories.
    assert_eq!(a.unsafe_sites.len(), 1);
    assert!(a.unsafe_sites[0].safety.is_some());
    assert!(a.lock_edges.iter().any(|e| e.from == "first" && e.to == "second"));
}

#[test]
fn bench_artifact_without_ci_gate_is_flagged() {
    use lkgp::analysis::SourceFile;
    let bench = "fn main() { out(\"BENCH_rogue.json\"); out(\"BENCH_hotpath.json\"); }\n";
    let input = AnalysisInput {
        src: Vec::new(),
        benches: vec![SourceFile { name: "rogue.rs".into(), text: bench.into() }],
        ci_script: Some("gate_file bench BENCH_hotpath.json".into()),
        docs: Vec::new(),
    };
    let a = analyze(&input, &AnalysisConfig::crate_default());
    let gates: Vec<_> = a.findings.iter().filter(|f| f.rule == Rule::BenchGate).collect();
    assert_eq!(gates.len(), 1, "{:?}", a.findings);
    assert!(gates[0].message.contains("BENCH_rogue.json"));
}

#[test]
fn fixture_doc_drift_fires_on_all_checks_with_pragma_honored() {
    use lkgp::analysis::SourceFile;
    let input = AnalysisInput {
        src: vec![SourceFile { name: "main.rs".into(), text: fixture("doc_drift.rs") }],
        benches: vec![SourceFile {
            name: "orphan.rs".into(),
            text: "fn main() { out(\"BENCH_unlisted.json\"); }\n".into(),
        }],
        // ci.sh gates the artifact, but docs/ci.md's inventory omits it:
        // bench_gate stays quiet, doc_drift fires.
        ci_script: Some("gate_file bench BENCH_unlisted.json".into()),
        docs: vec![
            SourceFile { name: "present.md".into(), text: "explains `--documented`".into() },
            SourceFile { name: "ci.md".into(), text: "artifacts: BENCH_known.json".into() },
        ],
    };
    let a = analyze(&input, &AnalysisConfig::crate_default());
    let drift = hits(&a, Rule::DocDrift);
    // absent.md (module doc, line 2), waived.md (pragma'd, line 6),
    // --undocumented (usage string, line 7), BENCH_unlisted (bench, line 1).
    assert_eq!(drift, vec![(1, false), (2, false), (6, true), (7, false)], "{:?}", a.findings);
    let msgs: Vec<&str> = a
        .findings
        .iter()
        .filter(|f| f.rule == Rule::DocDrift)
        .map(|f| f.message.as_str())
        .collect();
    assert!(msgs.iter().any(|m| m.contains("docs/absent.md")));
    assert!(msgs.iter().any(|m| m.contains("docs/waived.md")));
    assert!(msgs.iter().any(|m| m.contains("`--undocumented`")));
    assert!(msgs.iter().any(|m| m.contains("BENCH_unlisted.json")));
    // present.md, `--documented`, and BENCH_known.json are all clean, and
    // no other rule fires on the fixture.
    assert!(a.findings.iter().all(|f| f.rule == Rule::DocDrift), "{:?}", a.findings);
    assert_eq!(a.unjustified().len(), 3);
}

#[test]
fn shipped_docs_tree_is_loaded_and_consistent() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let input = AnalysisInput::load(root).expect("load crate sources");
    // The repo ships a docs tree; the doc-drift rule must actually be
    // exercising it (an empty set would skip-pass the whole rule).
    assert!(input.docs.len() >= 10, "only {} docs loaded", input.docs.len());
    assert!(input.docs.iter().any(|d| d.name == "index.md"));
    assert!(input.docs.iter().any(|d| d.name == "sampling.md"));
    let report = analyze(&input, &AnalysisConfig::crate_default());
    let drift: Vec<String> = report
        .findings
        .iter()
        .filter(|f| f.rule == Rule::DocDrift && f.justified.is_none())
        .map(|f| format!("{}:{} {}", f.file, f.line, f.message))
        .collect();
    assert!(drift.is_empty(), "doc drift in the shipped tree:\n{}", drift.join("\n"));
}
