//! Fault-tolerance e2e: the pool must degrade predictably under injected
//! faults — solver escalation recovers crippled solves, panic storms
//! quarantine only the faulting shard, and expired deadlines surface as
//! typed timeouts instead of hangs (docs/robustness.md).

use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use lkgp::coordinator::{
    Answer, CurveStore, PoolCfg, PredictClient, Query, Registry, Request, ServicePool, Snapshot,
};
use lkgp::gp::{Dataset, SolverCfg, Theta};
use lkgp::lcbench::{Preset, Task};
use lkgp::linalg::Matrix;
use lkgp::rng::Pcg64;
use lkgp::runtime::chaos::{ChaosEngine, ChaosStats, FaultPlan};
use lkgp::runtime::{Engine, RustEngine};
use lkgp::LkgpError;

/// Registry snapshot of a simulated task with prefix-observed curves.
fn snapshot_for(preset: Preset, n: usize, seed: u64) -> Snapshot {
    let mut rng = Pcg64::new(seed);
    let task = Task::generate(preset, n, &mut rng);
    let mut reg = Registry::new();
    for i in 0..n {
        let id = reg.add(task.configs.row(i).to_vec());
        let len = 3 + rng.below(8);
        for j in 0..len {
            reg.observe(id, task.curves[(i, j)], task.m()).unwrap();
        }
    }
    CurveStore::new(task.m()).snapshot(&reg).unwrap()
}

fn assert_answers_bit_equal(got: &[Answer], want: &[Answer]) {
    assert_eq!(got.len(), want.len());
    for (g, w) in got.iter().zip(want) {
        match (g, w) {
            (Answer::Final(a), Answer::Final(b)) => {
                assert_eq!(a.len(), b.len());
                for (x, y) in a.iter().zip(b) {
                    assert_eq!(x.0.to_bits(), y.0.to_bits(), "mean diverged");
                    assert_eq!(x.1.to_bits(), y.1.to_bits(), "variance diverged");
                }
            }
            (Answer::Variance(a), Answer::Variance(b)) => {
                assert_eq!(a.len(), b.len());
                for (x, y) in a.iter().zip(b) {
                    assert_eq!(x.to_bits(), y.to_bits(), "variance diverged");
                }
            }
            (Answer::Quantiles(a), Answer::Quantiles(b)) => {
                assert_eq!((a.rows(), a.cols()), (b.rows(), b.cols()));
                for (x, y) in a.data().iter().zip(b.data()) {
                    assert_eq!(x.to_bits(), y.to_bits(), "matrix answer diverged");
                }
            }
            other => panic!("answer kinds diverged: {other:?}"),
        }
    }
}

/// A shard whose engine is crippled to a one-iteration CG budget must
/// still answer — the escalation ladder climbs until a rung converges (at
/// worst the dense Cholesky fallback) — with answers matching a healthy
/// shard to solver tolerance, and the recovery observable in the shard's
/// `escalations` counter.
#[test]
fn crippled_cg_budget_recovers_through_escalation_ladder() {
    let snap = snapshot_for(Preset::FashionMnist, 8, 13);
    let theta = Theta::default_packed(7);
    let xq = Matrix::from_vec(2, 7, {
        let mut v = snap.all_x.row(0).to_vec();
        v.extend_from_slice(snap.all_x.row(5));
        v
    });
    let queries = vec![
        Query::MeanAtFinal { xq: xq.clone() },
        Query::Variance { xq },
    ];

    let healthy = ServicePool::spawn(
        vec![Box::<RustEngine>::default() as Box<dyn Engine>],
        PoolCfg { workers: 1, warm_start: false, ..Default::default() },
    );
    let want = healthy
        .handle(0)
        .query(snap.clone(), theta.clone(), queries.clone())
        .unwrap();
    assert_eq!(healthy.stats(0).solver_failures.load(Ordering::Relaxed), 0);

    let mut crippled = RustEngine::default();
    crippled.cfg.cg_max_iters = 1;
    let pool = ServicePool::spawn(
        vec![Box::new(crippled) as Box<dyn Engine>],
        PoolCfg { workers: 1, warm_start: false, ..Default::default() },
    );
    let got = pool
        .handle(0)
        .query(snap, theta, queries)
        .expect("the ladder must recover a one-iteration CG budget");
    assert!(
        pool.stats(0).escalations.load(Ordering::Relaxed) > 0,
        "recovery must be observable as escalations"
    );

    for (g, w) in got.iter().zip(&want) {
        match (g, w) {
            (Answer::Final(a), Answer::Final(b)) => {
                for (x, y) in a.iter().zip(b) {
                    assert!(x.0.is_finite() && x.1.is_finite() && x.1 > 0.0);
                    assert!(
                        (x.0 - y.0).abs() < 1e-5 && (x.1 - y.1).abs() < 1e-5,
                        "escalated answer {x:?} drifted from healthy {y:?}"
                    );
                }
            }
            (Answer::Variance(a), Answer::Variance(b)) => {
                for (x, y) in a.iter().zip(b) {
                    assert!(x.is_finite() && *x > 0.0);
                    assert!((x - y).abs() < 1e-5);
                }
            }
            other => panic!("answer kinds diverged: {other:?}"),
        }
    }
}

/// A panic storm on one shard must quarantine exactly that shard — typed
/// `Quarantined` rejections once the breaker trips — while sibling shards
/// keep serving answers bit-identical to a chaos-free pool.
#[test]
fn panic_storm_quarantines_only_the_faulting_shard() {
    let chaos_stats = Arc::new(ChaosStats::default());
    let storm = FaultPlan { panic_rate: 1.0, ..Default::default() };
    let engines: Vec<Box<dyn Engine>> = vec![
        Box::<RustEngine>::default(),
        Box::new(ChaosEngine::new(
            RustEngine::default(),
            storm,
            1,
            chaos_stats.clone(),
        )),
    ];
    let pool = ServicePool::spawn(
        engines,
        PoolCfg {
            workers: 2,
            warm_start: false,
            // long cool-down so the trip stays observable for the whole test
            breaker_cooldown: Duration::from_secs(600),
            ..Default::default()
        },
    );

    let snap0 = snapshot_for(Preset::FashionMnist, 8, 21);
    let snap1 = snapshot_for(Preset::Higgs, 8, 22);
    let theta = Theta::default_packed(7);
    let queries = |snap: &Snapshot| {
        let xq = Matrix::from_vec(1, 7, snap.all_x.row(0).to_vec());
        vec![
            Query::MeanAtFinal { xq: xq.clone() },
            Query::Quantiles { xq, ps: vec![0.1, 0.9] },
        ]
    };

    // storm the faulting shard: every request resolves to an error (the
    // panicked batch drops its replies; post-trip submits are rejected
    // typed) — never a hang
    for _ in 0..5 {
        let res = pool
            .handle(1)
            .query(snap1.clone(), theta.clone(), queries(&snap1));
        match res {
            Ok(a) => panic!("storm shard must not answer, got {a:?}"),
            Err(_) => {} // dropped replies or typed quarantine rejections
        }
    }
    // the breaker is fed by the worker just after the panicked batch is
    // caught, which can land moments after the client sees its dropped
    // reply — wait for the trip to be recorded before asserting on it
    let stats1 = pool.stats(1);
    let deadline = Instant::now() + Duration::from_secs(10);
    while stats1.quarantine_trips.load(Ordering::Relaxed) == 0 && Instant::now() < deadline {
        std::thread::yield_now();
    }
    assert!(
        stats1.panics_recovered.load(Ordering::Relaxed) >= 3,
        "every injected panic must be recovered"
    );
    assert!(
        stats1.quarantine_trips.load(Ordering::Relaxed) >= 1,
        "consecutive panics must trip the breaker"
    );
    assert!(chaos_stats.panics.load(Ordering::Relaxed) >= 3);
    match pool
        .handle(1)
        .query(snap1.clone(), theta.clone(), queries(&snap1))
    {
        Err(LkgpError::Quarantined { shard, failures, .. }) => {
            assert_eq!(shard, 1);
            assert!(failures >= 3);
        }
        other => panic!("post-trip submit must be rejected typed, got {other:?}"),
    }

    // the sibling shard is untouched: bit-identical to a chaos-free pool
    let clean = ServicePool::spawn(
        vec![Box::<RustEngine>::default() as Box<dyn Engine>],
        PoolCfg { workers: 1, warm_start: false, ..Default::default() },
    );
    let want = clean
        .handle(0)
        .query(snap0.clone(), theta.clone(), queries(&snap0))
        .unwrap();
    let got = pool
        .handle(0)
        .query(snap0.clone(), theta.clone(), queries(&snap0))
        .unwrap();
    assert_answers_bit_equal(&got, &want);
    assert_eq!(pool.stats(0).quarantine_trips.load(Ordering::Relaxed), 0);
    assert_eq!(pool.stats(0).panics_recovered.load(Ordering::Relaxed), 0);
}

/// A `RustEngine` whose `fit` blocks until the test sends a token: pins
/// the pool's single worker so a deadline-wrapped request expires while
/// queued.
struct GatedEngine {
    inner: RustEngine,
    gate: mpsc::Receiver<()>,
}

impl GatedEngine {
    fn pair() -> (mpsc::Sender<()>, Box<dyn Engine>) {
        let (tx, rx) = mpsc::channel();
        (tx, Box::new(GatedEngine { inner: RustEngine::default(), gate: rx }))
    }
}

impl Engine for GatedEngine {
    fn fit(&mut self, theta0: &[f64], data: &Dataset, seed: u64) -> lkgp::Result<Vec<f64>> {
        let _ = self.gate.recv();
        self.inner.fit(theta0, data, seed)
    }

    fn predict_final(
        &mut self,
        theta: &[f64],
        data: &Dataset,
        xq: &Matrix,
    ) -> lkgp::Result<Vec<(f64, f64)>> {
        self.inner.predict_final(theta, data, xq)
    }

    fn sample_curves(
        &mut self,
        theta: &[f64],
        data: &Dataset,
        xq: &Matrix,
        s: usize,
        seed: u64,
    ) -> lkgp::Result<Vec<Matrix>> {
        self.inner.sample_curves(theta, data, xq, s, seed)
    }

    fn predict_mean(&mut self, theta: &[f64], data: &Dataset, xq: &Matrix) -> lkgp::Result<Matrix> {
        self.inner.predict_mean(theta, data, xq)
    }

    fn session_cfg(&self) -> Option<SolverCfg> {
        self.inner.session_cfg()
    }

    fn name(&self) -> &'static str {
        "gated"
    }
}

/// A request whose deadline expires while it waits behind a busy writer
/// must come back as a typed `Timeout` — promptly, never a hang — and the
/// shard must count it.
#[test]
fn expired_deadline_is_shed_with_typed_timeout() {
    let (gate, engine) = GatedEngine::pair();
    let pool = ServicePool::spawn(
        vec![engine],
        PoolCfg { workers: 1, warm_start: false, max_replicas: 0, ..Default::default() },
    );
    let snap = snapshot_for(Preset::Airlines, 8, 31);
    let theta = Theta::default_packed(7);

    // pin the single worker on a gated refit
    let (ftx, frx) = mpsc::channel();
    pool.submit(
        0,
        Request::Refit {
            snapshot: snap.clone(),
            theta0: theta.clone(),
            seed: 3,
            resp: ftx,
        },
    )
    .unwrap();
    while pool.queue_depth(0) > 0 {
        std::thread::yield_now();
    }

    // queue a read with a deadline that expires behind the pinned writer
    let (rtx, rrx) = mpsc::channel();
    let xq = Matrix::from_vec(1, 7, snap.all_x.row(0).to_vec());
    pool.submit(
        0,
        Request::Deadline {
            deadline: Instant::now() + Duration::from_millis(20),
            inner: Box::new(Request::Query {
                snapshot: snap.clone(),
                theta: theta.clone(),
                queries: vec![Query::MeanAtFinal { xq }],
                resp: rtx,
            }),
        },
    )
    .unwrap();
    std::thread::sleep(Duration::from_millis(60));
    gate.send(()).unwrap();

    let reply = rrx
        .recv_timeout(Duration::from_secs(60))
        .expect("expired requests must be answered, never hang");
    match reply {
        Err(LkgpError::Timeout { shard, late_micros }) => {
            assert_eq!(shard, 0);
            assert!(late_micros > 0);
        }
        other => panic!("expected a typed Timeout, got {other:?}"),
    }
    assert_eq!(pool.stats(0).timeouts.load(Ordering::Relaxed), 1);
    frx.recv().unwrap().unwrap();
}
