//! Integration: the XLA artifact path and the pure-rust engine implement
//! the same math.
//!
//! The XLA half needs the vendored `xla` crate (`--features xla`) plus
//! `make artifacts`; those tests live in the feature-gated module below
//! and skip themselves when artifacts are absent. The rust-engine tests
//! always run.

#![allow(deprecated)] // exercises the deprecated free-function shims by design

use lkgp::gp::Theta;
use lkgp::lcbench;
use lkgp::linalg::Matrix;
use lkgp::runtime::{Engine, RustEngine};

#[test]
fn rust_engine_full_loop_without_artifacts() {
    // The fallback engine must be usable standalone.
    let mut rust = RustEngine::default();
    let data = lcbench::toy_dataset(10, 12, 3, 15);
    let theta0 = Theta::default_packed(3);
    let theta = rust.fit(&theta0, &data, 2).unwrap();
    let xq = Matrix::from_vec(2, 3, vec![0.2, 0.4, 0.6, 0.8, 0.1, 0.3]);
    let preds = rust.predict_final(&theta, &data, &xq).unwrap();
    assert_eq!(preds.len(), 2);
    let samples = rust.sample_curves(&theta, &data, &xq, 8, 3).unwrap();
    assert_eq!(samples.len(), 8);
}

#[test]
fn lbfgs_trainer_improves_mll_like_paper() {
    // Paper §B optimizes with L-BFGS; the probe-conditioned objective is
    // deterministic, so the quasi-Newton path must improve the exact MLL
    // at least as a first-order fit does.
    let data = lcbench::toy_dataset(12, 14, 3, 21);
    let theta0 = Theta::default_packed(3);
    let before = lkgp::gp::lkgp::mll_exact(&theta0, &data).unwrap();
    let mut eng = lkgp::runtime::RustEngine::with_lbfgs();
    let theta = eng.fit(&theta0, &data, 1).unwrap();
    let after = lkgp::gp::lkgp::mll_exact(&theta, &data).unwrap();
    assert!(after > before, "{before} -> {after}");
}

#[test]
fn warm_predict_parity_through_engine_trait() {
    // The warm-start entry point must agree with the cold path: identical
    // with no guess, tolerance-close (and cheaper on the training column)
    // with the converged alpha as guess.
    let data = lcbench::toy_dataset(10, 12, 3, 17);
    let theta = Theta::default_packed(3);
    let mut eng = RustEngine::default();
    let xq = Matrix::from_vec(2, 3, vec![0.2, 0.4, 0.6, 0.8, 0.1, 0.3]);
    let cold = eng.predict_final(&theta, &data, &xq).unwrap();
    let out = eng.predict_final_warm(&theta, &data, &xq, None).unwrap();
    assert_eq!(out.preds, cold);
    let alpha = out.alpha.expect("rust engine reports alpha");
    let warm = eng
        .predict_final_warm(&theta, &data, &xq, Some(&alpha))
        .unwrap();
    assert!(
        warm.cg_iters <= out.cg_iters,
        "warm {} vs cold {}",
        warm.cg_iters,
        out.cg_iters
    );
    for (a, b) in warm.preds.iter().zip(&cold) {
        assert!((a.0 - b.0).abs() < 0.05 && (a.1 - b.1).abs() < 0.05);
    }
}

#[cfg(feature = "xla")]
mod xla_parity {
    use lkgp::gp::lkgp::SolverCfg;
    use lkgp::gp::Theta;
    use lkgp::lcbench;
    use lkgp::linalg::Matrix;
    use lkgp::rng::Pcg64;
    use lkgp::runtime::{Engine, RustEngine, XlaEngine};

    fn xla_engine() -> Option<XlaEngine> {
        let dir = XlaEngine::default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        Some(XlaEngine::load(&dir).expect("load artifacts"))
    }

    #[test]
    fn mvm_matches_rust_operator() {
        let Some(mut eng) = xla_engine() else { return };
        let data = lcbench::toy_dataset(12, 14, 3, 1);
        let theta = Theta::default_packed(3);
        let mut rng = Pcg64::new(2);
        let v = Matrix::from_vec(12, 14, rng.normal_vec(12 * 14));

        let got = eng.mvm(&theta, &data, &v).unwrap();

        let th = Theta::unpack(&theta);
        let k1 = lkgp::gp::kernels::rbf(&data.x, &data.x, &th.lengthscales);
        let k2 = lkgp::gp::kernels::matern12(&data.t, &data.t, th.t_lengthscale, th.outputscale);
        let op = lkgp::gp::operator::MaskedKronOp::new(&k1, &k2, &data.mask, th.sigma2);
        let want = op.apply_mat(&v);
        assert!(got.max_abs_diff(&want) < 1e-10, "diff={}", got.max_abs_diff(&want));
    }

    #[test]
    fn mvm_padding_is_inert() {
        // A problem smaller than its bucket must produce identical results.
        let Some(mut eng) = xla_engine() else { return };
        let data = lcbench::toy_dataset(9, 11, 3, 3); // pads up to (16, 16)
        let theta = Theta::default_packed(3);
        let mut rng = Pcg64::new(4);
        let v = Matrix::from_vec(9, 11, rng.normal_vec(99));
        let got = eng.mvm(&theta, &data, &v).unwrap();
        let th = Theta::unpack(&theta);
        let k1 = lkgp::gp::kernels::rbf(&data.x, &data.x, &th.lengthscales);
        let k2 = lkgp::gp::kernels::matern12(&data.t, &data.t, th.t_lengthscale, th.outputscale);
        let op = lkgp::gp::operator::MaskedKronOp::new(&k1, &k2, &data.mask, th.sigma2);
        let want = op.apply_mat(&v);
        assert!(got.max_abs_diff(&want) < 1e-10);
    }

    #[test]
    fn mll_grad_matches_rust_engine() {
        let Some(mut eng) = xla_engine() else { return };
        // full bucket size so probes are comparable (same operator space)
        let data = lcbench::toy_dataset(16, 16, 3, 5);
        let theta = Theta::default_packed(3);
        let (xval, xgrad, _) = eng.mll_grad(&theta, &data, 11).unwrap();

        let mut rng = Pcg64::new(12);
        let probes = rng.rademacher_vec(64 * 16 * 16);
        let cfg = SolverCfg { probes: 64, ..Default::default() };
        let eval = lkgp::gp::lkgp::mll_value_grad(&theta, &data, &probes, &cfg).unwrap();

        // exact oracle anchors both
        let exact = lkgp::gp::lkgp::mll_exact(&theta, &data).unwrap();
        assert!(
            (xval - exact).abs() < 6.0,
            "xla value {xval} vs exact {exact}"
        );
        assert!((eval.value - exact).abs() < 6.0);
        // gradients agree directionally (different probe draws)
        let dot: f64 = xgrad.iter().zip(&eval.grad).map(|(a, b)| a * b).sum();
        let na: f64 = xgrad.iter().map(|g| g * g).sum::<f64>().sqrt();
        let nb: f64 = eval.grad.iter().map(|g| g * g).sum::<f64>().sqrt();
        assert!(dot / (na * nb) > 0.95, "cosine {}", dot / (na * nb));
    }

    #[test]
    fn predict_mean_parity() {
        let Some(mut eng) = xla_engine() else { return };
        let data = lcbench::toy_dataset(14, 16, 3, 6);
        let theta = Theta::default_packed(3);
        let mut rng = Pcg64::new(7);
        let xq = Matrix::from_vec(4, 3, rng.uniform_vec(12, 0.0, 1.0));
        let got = eng.predict_mean(&theta, &data, &xq).unwrap();
        let cfg = SolverCfg { cg_tol: 1e-4, ..Default::default() };
        let (want, _) = lkgp::gp::lkgp::predict_mean(&theta, &data, &xq, &cfg).unwrap();
        // both use CG at tol 1e-2 (artifact) vs 1e-4: compare loosely
        assert!(
            got.max_abs_diff(&want) < 5e-2,
            "diff={}",
            got.max_abs_diff(&want)
        );
    }

    #[test]
    fn fit_improves_exact_mll_both_engines() {
        let Some(mut eng) = xla_engine() else { return };
        let data = lcbench::toy_dataset(16, 16, 3, 8);
        let theta0 = Theta::default_packed(3);
        let before = lkgp::gp::lkgp::mll_exact(&theta0, &data).unwrap();

        let theta_xla = eng.fit(&theta0, &data, 1).unwrap();
        let after_xla = lkgp::gp::lkgp::mll_exact(&theta_xla, &data).unwrap();
        assert!(after_xla > before, "xla fit {before} -> {after_xla}");

        let mut rust = RustEngine::default();
        let theta_rust = rust.fit(&theta0, &data, 1).unwrap();
        let after_rust = lkgp::gp::lkgp::mll_exact(&theta_rust, &data).unwrap();
        assert!(after_rust > before, "rust fit {before} -> {after_rust}");
    }

    #[test]
    fn posterior_samples_have_consistent_moments() {
        let Some(mut eng) = xla_engine() else { return };
        let data = lcbench::toy_dataset(10, 16, 3, 9);
        let theta = Theta::default_packed(3);
        let mut rng = Pcg64::new(10);
        let xq = Matrix::from_vec(2, 3, rng.uniform_vec(6, 0.0, 1.0));

        let xla_samples = eng.sample_curves(&theta, &data, &xq, 256, 11).unwrap();
        let cfg = SolverCfg::default();
        let (want_mean, _) = lkgp::gp::lkgp::predict_mean(&theta, &data, &xq, &cfg).unwrap();

        let n = data.n();
        for qi in 0..2 {
            for j in [0usize, 8, 15] {
                let emp: f64 = xla_samples.iter().map(|s| s[(n + qi, j)]).sum::<f64>()
                    / xla_samples.len() as f64;
                assert!(
                    (emp - want_mean[(qi, j)]).abs() < 0.25,
                    "qi={qi} j={j} emp={emp} want={}",
                    want_mean[(qi, j)]
                );
            }
        }
    }

    #[test]
    fn engines_agree_on_final_predictions() {
        let Some(mut eng) = xla_engine() else { return };
        let data = lcbench::toy_dataset(12, 16, 3, 13);
        let theta = Theta::default_packed(3);
        let mut rng = Pcg64::new(14);
        let xq = Matrix::from_vec(3, 3, rng.uniform_vec(9, 0.0, 1.0));
        let mut rust = RustEngine::default();
        let exact = rust.predict_final(&theta, &data, &xq).unwrap();
        let sampled = eng.predict_final(&theta, &data, &xq).unwrap();
        for (e, s) in exact.iter().zip(&sampled) {
            assert!((e.0 - s.0).abs() < 3.0 * (e.1.sqrt() / 4.0 + 0.02), "mean {} vs {}", e.0, s.0);
            assert!(s.1 > 0.0);
        }
    }
}
