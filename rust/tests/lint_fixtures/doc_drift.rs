//! Fixture: doc drift. The module doc cites docs/present.md (exists in
//! the test's doc set) and docs/absent.md (dangling — a finding).

fn main() {
    // lint: allow(doc_drift) — fixture waiver: historical pointer kept on purpose
    let _legacy = "docs/waived.md";
    eprintln!("usage: tool [--documented N] [--undocumented N]");
}
