//! Lint fixture (never compiled — loaded as text by tests/lint.rs).
//! `misses` is incremented but never observed; `hits` is read by the
//! report path. The drift rule must flag exactly `misses`.
use std::sync::atomic::{AtomicU64, Ordering};

pub struct FixtureStats {
    pub hits: AtomicU64,
    pub misses: AtomicU64,
}

impl FixtureStats {
    pub fn note(&self) {
        self.misses.fetch_add(1, Ordering::Relaxed);
    }

    pub fn report(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }
}
