//! Lint fixture (never compiled — loaded as text by tests/lint.rs).
//! `forward` acquires alpha then beta; `backward` holds beta across a
//! call to `tail`, which acquires alpha — a beta -> alpha call-graph
//! edge that closes a lock-order cycle.
use std::sync::Mutex;

pub struct Shared {
    pub alpha: Mutex<u64>,
    pub beta: Mutex<u64>,
}

pub fn forward(s: &Shared) -> u64 {
    let a = s.alpha.lock().unwrap();
    let b = s.beta.lock().unwrap();
    *a + *b
}

pub fn backward(s: &Shared) -> u64 {
    let b = s.beta.lock().unwrap();
    *b + tail(s)
}

fn tail(s: &Shared) -> u64 {
    let a = s.alpha.lock().unwrap();
    *a
}
