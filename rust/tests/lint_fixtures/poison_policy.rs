//! Lint fixture (never compiled — loaded as text by tests/lint.rs).
//! Poison-policy mismatches both ways: `work` is registered fail-loud
//! but recovers, `memo` is registered recover but unwraps.
use std::sync::Mutex;

pub struct State {
    pub work: Mutex<Vec<u64>>,
    pub memo: Mutex<u64>,
}

pub fn drain(s: &State) -> usize {
    let q = s.work.lock().unwrap_or_else(|p| p.into_inner());
    q.len()
}

pub fn peek(s: &State) -> u64 {
    let m = s.memo.lock().unwrap();
    *m
}
