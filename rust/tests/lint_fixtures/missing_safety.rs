//! Lint fixture (never compiled — loaded as text by tests/lint.rs).
//! One raw-pointer read has no SAFETY argument; the other carries one.

pub fn undocumented(p: *const u64) -> u64 {
    unsafe { *p }
}

pub fn documented(p: *const u64) -> u64 {
    // SAFETY: fixture contract — `p` is valid, aligned, and unaliased
    // for the duration of this call.
    unsafe { *p }
}
