//! Lint fixture (never compiled — loaded as text by tests/lint.rs).
//! A float-literal equality and a NaN-unsafe ordering must be flagged;
//! the bit-exact and tolerance-based comparisons must not.

pub fn bad_eq(x: f64) -> bool {
    x == 0.0
}

pub fn bad_sort(v: &mut [f64]) {
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
}

pub fn good_bits(x: f64, y: f64) -> bool {
    x.to_bits() == y.to_bits()
}

pub fn good_tol(x: f64) -> bool {
    (x - 1.0).abs() < 1e-12
}
