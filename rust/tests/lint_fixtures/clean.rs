//! Lint fixture (never compiled — loaded as text by tests/lint.rs).
//! Fully compliant code: consistently ordered fail-loud locks, a
//! documented unsafe block, bit-exact float identity, a tolerance
//! compare, and a stats struct whose every counter is observed. The
//! lint must report nothing.
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

pub struct CleanStats {
    pub served: AtomicU64,
}

pub struct Clean {
    pub first: Mutex<u64>,
    pub second: Mutex<u64>,
    pub stats: CleanStats,
}

pub fn ordered(c: &Clean) -> u64 {
    let a = c.first.lock().unwrap();
    let b = c.second.lock().unwrap();
    c.stats.served.load(Ordering::Relaxed) + *a + *b
}

pub fn bits(x: f64, y: f64) -> bool {
    x.to_bits() == y.to_bits()
}

pub fn tol(x: f64) -> bool {
    (x - 1.0).abs() < 1e-12
}

pub fn read_raw(p: *const u64) -> u64 {
    // SAFETY: fixture contract — `p` is valid, aligned, and unaliased
    // for the duration of this call.
    unsafe { *p }
}
