//! Lint fixture (never compiled — loaded as text by tests/lint.rs).
//! The driving test registers this file as a hot path: the unwrap, the
//! expect and the `unreachable!` must be flagged, the poison-protocol
//! `.wait(..).unwrap()` exempted, and the pragma'd site justified.

pub fn serve(input: Option<u64>, cond: &Cond, g: Guard) -> u64 {
    let a = input.unwrap();
    let b = input.expect("fixture: must be set");
    if a + b > 100 {
        unreachable!("fixture: bounded by caller");
    }
    let woke = cond.wait(g).unwrap();
    // lint: allow(panic) — fixture: fail-loud is the documented contract
    let c = input.unwrap();
    a + b + c + woke
}
