//! Data-plane e2e: hardened `Task::load_json` on adversarial dumps, the
//! `Corpus` implementations (sim / JSON-dir / trace-pinned), per-task
//! error isolation, the checked-in `data/lcbench_mini` fixture, and lazy
//! pool admission (`ServicePool::from_corpus`) with idle eviction.

use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use lkgp::coordinator::{
    CurveStore, EngineFactory, PoolCfg, PredictClient, Registry, ServicePool, Snapshot,
};
use lkgp::gp::Theta;
use lkgp::lcbench::corpus::{Corpus, JsonDirCorpus, SimCorpus, TraceCorpus};
use lkgp::lcbench::Task;
use lkgp::linalg::Matrix;
use lkgp::runtime::{Engine, RustEngine};

fn repo_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("rust/ lives under the repo root")
        .to_path_buf()
}

/// Unique scratch dir per test (std-only; no tempfile crate offline).
fn scratch_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "lkgp_corpus_{tag}_{}_{}",
        std::process::id(),
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos()
    ));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

// ---------------------------------------------------------------------------
// Task::load_json adversarial inputs

#[test]
fn load_json_rejects_nan_and_inf() {
    for bad in [
        r#"{"configs": [[0.1]], "curves": [[NaN]]}"#,
        r#"{"configs": [[0.1]], "curves": [[Infinity]]}"#,
    ] {
        // our parser rejects bare NaN/Infinity tokens outright
        assert!(Task::load_json("t", bad).is_err(), "{bad}");
    }
    // a numeric overflow that parses to inf must still be rejected
    let huge = r#"{"configs": [[0.1]], "curves": [[1e999]]}"#;
    assert!(Task::load_json("t", huge).is_err());
    // null mid-curve is a non-number, not a silent gap
    let nul = r#"{"configs": [[0.1]], "curves": [[0.5, null, 0.7]]}"#;
    assert!(Task::load_json("t", nul).is_err());
}

#[test]
fn load_json_rejects_ragged_configs_and_empty_curves() {
    let ragged_cfg = r#"{"configs": [[0.1, 0.2], [0.3]], "curves": [[0.5], [0.6]]}"#;
    let err = Task::load_json("t", ragged_cfg).unwrap_err().to_string();
    assert!(err.contains("config row 1"), "{err}");

    let empty_curve = r#"{"configs": [[0.1], [0.2]], "curves": [[0.5], []]}"#;
    let err = Task::load_json("t", empty_curve).unwrap_err().to_string();
    assert!(err.contains("curve row 1"), "{err}");

    let count_mismatch = r#"{"configs": [[0.1]], "curves": [[0.5], [0.6]]}"#;
    assert!(Task::load_json("t", count_mismatch).is_err());

    let zero_dim = r#"{"configs": [[], []], "curves": [[0.5], [0.6]]}"#;
    assert!(Task::load_json("t", zero_dim).is_err());
}

#[test]
fn load_json_rejects_duplicate_config_ids() {
    let dup = r#"{"ids": [7, 7], "configs": [[0.1], [0.2]], "curves": [[0.5], [0.6]]}"#;
    let err = Task::load_json("t", dup).unwrap_err().to_string();
    assert!(err.contains("duplicate config id"), "{err}");

    let ok = r#"{"ids": [7, 8], "configs": [[0.1], [0.2]], "curves": [[0.5], [0.6]]}"#;
    assert!(Task::load_json("t", ok).is_ok());

    let wrong_len = r#"{"ids": [7], "configs": [[0.1], [0.2]], "curves": [[0.5], [0.6]]}"#;
    assert!(Task::load_json("t", wrong_len).is_err());
}

#[test]
fn load_json_accepts_ragged_curves_as_early_stopping() {
    let text = r#"{"configs": [[0.1], [0.2]], "curves": [[0.5, 0.6, 0.7], [0.4]]}"#;
    let task = Task::load_json("t", text).unwrap();
    assert_eq!(task.m(), 3);
    assert_eq!(task.lengths, vec![3, 1]);
    assert!(task.mask_density() < 1.0);
}

// ---------------------------------------------------------------------------
// JsonDirCorpus: lazy parse + per-task error isolation

fn write_task(dir: &PathBuf, name: &str, text: &str) {
    std::fs::write(dir.join(name), text).unwrap();
}

fn good_task_json(v: f64) -> String {
    format!(
        r#"{{"configs": [[0.1, {v}], [0.3, 0.4], [0.5, 0.6]],
            "curves": [[0.5, 0.6], [0.4, 0.5], [0.3]]}}"#
    )
}

#[test]
fn json_dir_corpus_isolates_one_corrupt_file() {
    let dir = scratch_dir("isolate");
    write_task(&dir, "a.json", &good_task_json(0.11));
    write_task(&dir, "b.json", "{\"configs\": [[0.1]], \"curves\": [[");
    write_task(&dir, "c.json", &good_task_json(0.22));
    write_task(&dir, "d.json", &good_task_json(0.33));
    write_task(&dir, "notes.txt", "not a task");

    let corpus = JsonDirCorpus::open(&dir).unwrap();
    assert_eq!(corpus.len(), 4, "only *.json files are tasks");
    let mut ok = 0;
    let mut failed = Vec::new();
    for (id, task) in corpus.tasks() {
        match task {
            Ok(t) => {
                ok += 1;
                assert_eq!(t.n(), 3);
                assert_eq!(t.lengths, vec![2, 2, 1]);
            }
            Err(_) => failed.push(id),
        }
    }
    assert_eq!(ok, 3, "three well-formed tasks must serve");
    assert_eq!(failed, vec![1], "only b.json (sorted order) fails");
    // metadata for a good task works; the corrupt one keeps erroring
    let meta = corpus.meta(0).unwrap();
    assert_eq!((meta.n, meta.m, meta.d), (3, 2, 2));
    assert!((meta.mask_density - 5.0 / 6.0).abs() < 1e-12);
    assert!(corpus.meta(1).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn json_dir_fingerprint_tracks_content() {
    let dir = scratch_dir("fp");
    write_task(&dir, "a.json", &good_task_json(0.5));
    let corpus = JsonDirCorpus::open(&dir).unwrap();
    let fp1 = corpus.fingerprint();
    assert!(fp1.starts_with("dir-"), "{fp1}");
    assert_eq!(fp1, JsonDirCorpus::open(&dir).unwrap().fingerprint());
    write_task(&dir, "a.json", &good_task_json(0.6));
    let fp2 = JsonDirCorpus::open(&dir).unwrap().fingerprint();
    assert_ne!(fp1, fp2, "byte change must re-print");
    // TraceCorpus pin verification: the stale fingerprint is refused
    assert!(TraceCorpus::dir(dir.to_str().unwrap(), Some(&fp1)).is_err());
    assert!(TraceCorpus::dir(dir.to_str().unwrap(), Some(&fp2)).is_ok());
    std::fs::remove_dir_all(&dir).ok();
}

/// The per-file digest cache must revalidate, not memoize: the SAME
/// corpus instance notices a file rewritten after its first fingerprint
/// call (streaming ingestion appends to dumps between generations), and
/// repeated calls on unchanged files stay stable and cheap (cache keyed
/// by `(path, mtime, len)` — only changed files are re-read).
#[test]
fn json_dir_fingerprint_revalidates_per_file() {
    let dir = scratch_dir("fp_stream");
    write_task(&dir, "a.json", &good_task_json(0.5));
    write_task(&dir, "b.json", &good_task_json(0.7));
    let corpus = JsonDirCorpus::open(&dir).unwrap();
    let fp1 = corpus.fingerprint();
    assert_eq!(fp1, corpus.fingerprint(), "unchanged corpus must re-print identically");

    // rewrite one file with different content *and length* (length is
    // part of the cache key, so this invalidates even on filesystems
    // with coarse mtime granularity)
    let grown = r#"{"configs": [[0.1, 0.2], [0.3, 0.4], [0.5, 0.6]],
            "curves": [[0.5, 0.6, 0.65], [0.4, 0.5], [0.3]]}"#;
    write_task(&dir, "b.json", grown);
    let fp2 = corpus.fingerprint();
    assert_ne!(fp1, fp2, "same instance must notice the rewritten file");
    // and a fresh instance (cold cache) agrees on the new print
    assert_eq!(fp2, JsonDirCorpus::open(&dir).unwrap().fingerprint());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn empty_dir_is_an_error() {
    let dir = scratch_dir("empty");
    assert!(JsonDirCorpus::open(&dir).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------------
// The checked-in fixture corpus

#[test]
fn fixture_corpus_is_real_shaped_and_fully_servable() {
    let corpus = JsonDirCorpus::open(repo_root().join("data/lcbench_mini")).unwrap();
    assert!(
        (8..=16).contains(&corpus.len()),
        "fixture holds 8-16 tasks, got {}",
        corpus.len()
    );
    let mut ragged = 0;
    for (id, task) in corpus.tasks() {
        let task = task.unwrap_or_else(|e| panic!("fixture task {id} must load: {e}"));
        assert_eq!(task.configs.cols(), 7, "LCBench d = 7");
        assert!(task.n() >= 8);
        assert!(task.m() >= 10);
        for v in task.curves.data() {
            assert!(v.is_finite() && (0.0..=1.0).contains(v));
        }
        if task.mask_density() < 1.0 {
            ragged += 1;
        }
    }
    assert!(ragged >= 4, "fixture must carry early-stopped rows ({ragged})");
}

// ---------------------------------------------------------------------------
// SimCorpus + TraceCorpus pins

#[test]
fn trace_corpus_sim_pin_reproduces_the_sim_corpus() {
    let sim = SimCorpus::new(3, 8, 17);
    let pinned = TraceCorpus::sim(3, 8, 17);
    assert_eq!(sim.fingerprint(), pinned.fingerprint());
    assert_eq!(
        sim.task(2).unwrap().curves.data(),
        pinned.task(2).unwrap().curves.data()
    );
    // the pin carries the reconstruction parameters
    let pin = sim.trace_pin();
    assert!(pin.iter().any(|(k, _)| k == "corpus"));
    assert!(pin.iter().any(|(k, _)| k == "configs"));
    assert!(pin.iter().any(|(k, _)| k == "seed"));
}

// ---------------------------------------------------------------------------
// Lazy pool admission from a corpus + idle eviction

fn tiny_snapshot_for(task: &Arc<Task>) -> Snapshot {
    let mut reg = Registry::new();
    for i in 0..task.n() {
        let id = reg.add(task.configs.row(i).to_vec());
        for j in 0..task.lengths[i].min(3) {
            reg.observe(id, task.curves[(i, j)], 6).unwrap();
        }
    }
    CurveStore::new(6).snapshot(&reg).unwrap()
}

#[test]
fn from_corpus_materializes_lazily_and_evicts_idle_shards() {
    let corpus = SimCorpus::new(6, 6, 3);
    let factory: EngineFactory = Box::new(|_| Box::<RustEngine>::default() as Box<dyn Engine>);
    let pool = ServicePool::from_corpus(
        &corpus,
        factory,
        PoolCfg { workers: 2, ..Default::default() },
    );
    assert_eq!(pool.shards(), 6);
    assert_eq!(pool.materialized(), 0, "admission must not build engines");
    assert_eq!(pool.live_shards(), 0);
    assert_eq!(pool.corpus_fingerprint(), Some("sim-t6-c6-s3"));

    // touch shards 0 and 1 only
    let theta = Theta::default_packed(7);
    for t in 0..2usize {
        let task = corpus.task(t).unwrap();
        let snap = tiny_snapshot_for(&task);
        let xq = Matrix::from_vec(1, 7, snap.all_x.row(0).to_vec());
        let preds = pool.handle(t).predict_final(snap, theta.clone(), xq).unwrap();
        assert!(preds[0].0.is_finite() && preds[0].1 > 0.0);
    }
    assert_eq!(pool.materialized(), 2, "only touched shards materialize");
    assert_eq!(pool.live_shards(), 2);

    // sweep 1 records watermarks; later sweeps free the now-quiet shards
    let mut evicted = pool.evict_idle();
    let deadline = Instant::now() + Duration::from_secs(5);
    while evicted < 2 && Instant::now() < deadline {
        std::thread::yield_now();
        evicted += pool.evict_idle();
    }
    assert_eq!(evicted, 2, "both quiet shards must evict");
    assert_eq!(pool.live_shards(), 0);
    assert_eq!(pool.evicted(), 2);

    // an evicted shard re-materializes transparently on its next request
    let task = corpus.task(0).unwrap();
    let snap = tiny_snapshot_for(&task);
    let xq = Matrix::from_vec(1, 7, snap.all_x.row(0).to_vec());
    let preds = pool.handle(0).predict_final(snap, theta, xq).unwrap();
    assert!(preds[0].0.is_finite());
    assert_eq!(pool.materialized(), 3, "re-materialization counts again");
    assert_eq!(pool.live_shards(), 1);
}

#[test]
fn spawn_pools_do_not_evict() {
    let engines: Vec<Box<dyn Engine>> =
        vec![Box::<RustEngine>::default() as Box<dyn Engine>];
    let pool = ServicePool::spawn(engines, PoolCfg { workers: 1, ..Default::default() });
    assert_eq!(pool.evict_idle(), 0);
    assert_eq!(pool.evict_idle(), 0, "caller-owned engines are never torn down");
    assert_eq!(pool.live_shards(), 1);
}

// ---------------------------------------------------------------------------
// Pre-warm on refit completion

#[test]
fn refit_prewarms_the_fresh_generation() {
    let corpus = SimCorpus::new(1, 6, 9);
    let task = corpus.task(0).unwrap();
    let snap = tiny_snapshot_for(&task);
    let engines: Vec<Box<dyn Engine>> =
        vec![Box::<RustEngine>::default() as Box<dyn Engine>];
    let pool = ServicePool::spawn(
        engines,
        PoolCfg { workers: 1, warm_start: true, prewarm: true, ..Default::default() },
    );
    let handle = pool.handle(0);
    // refit a never-queried generation: the writer must pre-warm it
    let fitted = handle.refit(snap.clone(), vec![], 4).unwrap();
    assert_eq!(pool.stats(0).prewarmed.load(Ordering::Relaxed), 1);
    assert_eq!(
        pool.stats(0).engine_solves.load(Ordering::Relaxed),
        0,
        "pre-warm work must not count as a query-path solve"
    );
    // the first read against the fresh fit exact-hits the pre-warmed
    // lineage instead of cold-missing
    let xq = Matrix::from_vec(1, 7, snap.all_x.row(0).to_vec());
    let preds = handle.predict_final(snap.clone(), fitted, xq).unwrap();
    assert!(preds[0].0.is_finite() && preds[0].1 > 0.0);
    let stats = pool.stats(0);
    assert_eq!(stats.warm_cache_hits.load(Ordering::Relaxed), 1);
    assert_eq!(stats.warm_cache_misses.load(Ordering::Relaxed), 0);
    assert_eq!(stats.engine_solves.load(Ordering::Relaxed), 1);
}

#[test]
fn prewarm_skips_generations_that_already_have_lineage() {
    let corpus = SimCorpus::new(1, 6, 10);
    let task = corpus.task(0).unwrap();
    let snap = tiny_snapshot_for(&task);
    let engines: Vec<Box<dyn Engine>> =
        vec![Box::<RustEngine>::default() as Box<dyn Engine>];
    let pool = ServicePool::spawn(
        engines,
        PoolCfg { workers: 1, warm_start: true, prewarm: true, ..Default::default() },
    );
    let handle = pool.handle(0);
    let theta = Theta::default_packed(7);
    let xq = Matrix::from_vec(1, 7, snap.all_x.row(0).to_vec());
    // a query fits the generation first (caches alpha + cross lineage)
    handle.predict_final(snap.clone(), theta.clone(), xq).unwrap();
    // the refit must NOT clobber that richer lineage with a prewarm
    handle.refit(snap, theta, 4).unwrap();
    assert_eq!(pool.stats(0).prewarmed.load(Ordering::Relaxed), 0);
}

#[test]
fn prewarm_disabled_by_config() {
    let corpus = SimCorpus::new(1, 6, 11);
    let task = corpus.task(0).unwrap();
    let snap = tiny_snapshot_for(&task);
    let engines: Vec<Box<dyn Engine>> =
        vec![Box::<RustEngine>::default() as Box<dyn Engine>];
    let pool = ServicePool::spawn(
        engines,
        PoolCfg { workers: 1, warm_start: true, prewarm: false, ..Default::default() },
    );
    pool.handle(0).refit(snap, vec![], 4).unwrap();
    assert_eq!(pool.stats(0).prewarmed.load(Ordering::Relaxed), 0);
}

// ---------------------------------------------------------------------------
// Preconditioner rank observability

#[test]
fn pool_report_exposes_preconditioner_rank() {
    let corpus = SimCorpus::new(1, 8, 12);
    let task = corpus.task(0).unwrap();
    let snap = tiny_snapshot_for(&task);
    let mut eng = RustEngine::default();
    eng.cfg.precond = lkgp::gp::PrecondCfg::Auto;
    let pool = ServicePool::spawn(
        vec![Box::new(eng) as Box<dyn Engine>],
        PoolCfg { workers: 1, ..Default::default() },
    );
    let theta = Theta::default_packed(7);
    let xq = Matrix::from_vec(1, 7, snap.all_x.row(0).to_vec());
    pool.handle(0).predict_final(snap, theta, xq).unwrap();
    let rank = pool.stats(0).precond_rank.load(Ordering::Relaxed);
    assert!(rank > 0, "Auto preconditioning must report its rank");
}
