//! Integration: the full coordinator loop over the simulated LCBench
//! workload, against both engines.

use lkgp::coordinator::{
    EpochRunner, Policy, PredictionService, Scheduler, SchedulerCfg, TrialId,
};
use lkgp::lcbench::{Preset, Task};
use lkgp::rng::Pcg64;
use lkgp::runtime::RustEngine;

struct SimRunner {
    task: Task,
}

impl EpochRunner for SimRunner {
    fn run_epoch(&mut self, trial: TrialId, _config: &[f64], epoch: usize) -> f64 {
        self.task.curves[(trial.0, epoch.min(self.task.m() - 1))]
    }
}

fn run_with(engine: Box<dyn lkgp::runtime::Engine>, seed: u64) -> (lkgp::coordinator::RunReport, f64) {
    let mut rng = Pcg64::new(seed);
    let task = Task::generate(Preset::FashionMnist, 16, &mut rng);
    let oracle = (0..task.n())
        .map(|i| task.curves[(i, task.m() - 1)])
        .fold(f64::NEG_INFINITY, f64::max);
    let cfg = SchedulerCfg {
        max_concurrent: 4,
        refit_every: 5,
        epoch_budget: 160,
        policy: Policy::PredictedFinal { delta: 0.0, threshold: 0.95 },
        seed,
    };
    let mut sched = Scheduler::new(task.m(), cfg);
    let configs: Vec<Vec<f64>> = (0..task.n()).map(|i| task.configs.row(i).to_vec()).collect();
    sched.add_candidates(&configs);
    let service = PredictionService::spawn(engine);
    let mut runner = SimRunner { task };
    let report = sched.run(&mut runner, &service).unwrap();
    (report, oracle)
}

#[test]
fn coordinator_with_rust_engine_finds_good_config() {
    let (report, oracle) = run_with(Box::<RustEngine>::default(), 1);
    assert!(report.epochs_spent <= 165);
    assert!(
        report.best_value > oracle - 0.1,
        "best={} oracle={oracle}",
        report.best_value
    );
    // the freeze-thaw loop spends far less than exhaustive training
    assert!(report.epochs_spent < 16 * 52 / 2);
}

#[cfg(feature = "xla")]
#[test]
fn coordinator_with_xla_engine_when_available() {
    let dir = lkgp::runtime::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let (report, oracle) = run_with(lkgp::runtime::open_engine(true), 2);
    assert!(
        report.best_value > oracle - 0.12,
        "best={} oracle={oracle}",
        report.best_value
    );
    assert!(report.batch_factor >= 1.0);
}

#[test]
fn deterministic_given_seed() {
    let (r1, _) = run_with(Box::<RustEngine>::default(), 7);
    let (r2, _) = run_with(Box::<RustEngine>::default(), 7);
    assert_eq!(r1.epochs_spent, r2.epochs_spent);
    assert_eq!(r1.best_value, r2.best_value);
    assert_eq!(r1.trace, r2.trace);
}
